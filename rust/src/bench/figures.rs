//! Figure harnesses — regenerate every result figure of the paper's
//! evaluation (§6): Fig 11, Fig 12, Fig 13, plus the §5.2 sync-overhead
//! claim (E4) and the §6.3 message-reduction claim (E5).
//!
//! Each harness reports two planes side by side:
//!
//! * **DES** — the discrete-event simulator run end-to-end on a reduced-scale
//!   panel (the DES is exact w.r.t. the cost model but its host run-time
//!   scales with message count).  Reduced panels use a 10:1 marker:haplotype
//!   aspect so fan-in stays representative; the reduction is printed.
//! * **Analytic** — the closed-form steady-state model (cross-validated
//!   against the DES; see `imputation::analytic`) evaluated at the *paper's*
//!   full scale: 1024 threads/board, aspect 100:1, 10,000 targets.
//!
//! The x86 denominator is the dense three-loop baseline: measured directly at
//! DES scale, throughput-extrapolated at full scale (marked `~`).

use crate::imputation::analytic::{AppKind, Workload, predict};
use crate::model::baseline::Method;
use crate::poets::costmodel::CostModel;
use crate::poets::termination;
use crate::poets::topology::ClusterConfig;
use crate::session::{EngineSpec, ImputeSession, Workload as SessionWorkload};
use crate::util::json::Json;
use crate::util::table::{Table, fmt_count, fmt_secs, fmt_speedup};
use crate::workload::panelgen::{PanelConfig, annotated_markers};
use crate::workload::scenarios;

use super::x86::X86Cost;

/// Sweep options shared by the figure harnesses.
#[derive(Clone, Copy, Debug)]
pub struct FigOpts {
    /// DES panel states per board (reduced scale; paper scale is 1024).
    pub des_states_per_board: usize,
    /// DES target count (steady-state needs ≳ M; kept small for run-time).
    pub des_targets: usize,
    /// Full-scale target count for the analytic plane (paper: 10,000).
    pub full_targets: usize,
    /// Skip the DES plane entirely (analytic-only sweeps are instant).
    pub skip_des: bool,
    pub seed: u64,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts {
            des_states_per_board: 128,
            des_targets: 12,
            full_targets: 10_000,
            skip_des: false,
            seed: 2023,
        }
    }
}

/// One row of a figure sweep.
#[derive(Clone, Debug)]
pub struct FigRow {
    pub x: String,
    pub panel: String,
    pub des_speedup: Option<f64>,
    pub des_poets_s: Option<f64>,
    pub des_x86_s: Option<f64>,
    pub full_speedup: f64,
    pub full_poets_s: f64,
    pub full_x86_s: f64,
    pub messages: Option<u64>,
}

/// A completed figure report.
#[derive(Clone, Debug)]
pub struct FigReport {
    pub title: String,
    pub x_label: String,
    pub rows: Vec<FigRow>,
}

impl FigReport {
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            &self.x_label,
            "panel(full)",
            "DES poets",
            "DES x86",
            "DES speedup",
            "full poets~",
            "full x86~",
            "full speedup~",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.x.clone(),
                r.panel.clone(),
                r.des_poets_s.map_or("-".into(), fmt_secs),
                r.des_x86_s.map_or("-".into(), fmt_secs),
                r.des_speedup.map_or("-".into(), fmt_speedup),
                fmt_secs(r.full_poets_s),
                fmt_secs(r.full_x86_s),
                fmt_speedup(r.full_speedup),
            ]);
        }
        format!("## {}\n{}", self.title, t.render())
    }

    pub fn to_json(&self) -> Json {
        let mut rows = Json::Arr(vec![]);
        for r in &self.rows {
            let mut o = Json::obj();
            o.set("x", r.x.clone())
                .set("panel", r.panel.clone())
                .set("full_speedup", r.full_speedup)
                .set("full_poets_s", r.full_poets_s)
                .set("full_x86_s", r.full_x86_s);
            if let Some(s) = r.des_speedup {
                o.set("des_speedup", s);
            }
            if let Some(m) = r.messages {
                o.set("des_messages", m);
            }
            rows.push(o);
        }
        let mut j = Json::obj();
        j.set("title", self.title.clone()).set("rows", rows);
        j
    }
}

fn des_panel_cfg(states: usize, annot_ratio: f64, seed: u64) -> PanelConfig {
    let (n_hap, n_mark) = scenarios::aspect_for_states_ratio(states, 10.0);
    PanelConfig {
        n_hap,
        n_mark,
        maf: 0.05,
        annot_ratio,
        seed,
        ..PanelConfig::default()
    }
}

fn des_run_raw(
    cfg: &PanelConfig,
    boards: usize,
    states_per_thread: usize,
    n_targets: usize,
) -> (f64, f64, u64) {
    let wl = SessionWorkload::synthetic(cfg, n_targets);
    let x86 = X86Cost::measure_raw_batch(wl.panel(), wl.targets(), Method::DenseThreeLoop);
    let report = ImputeSession::new(wl)
        .engine(EngineSpec::Event)
        .boards(boards)
        .states_per_thread(states_per_thread)
        .run()
        .expect("event plane is always available");
    (
        report.sim_seconds.expect("event plane reports sim time"),
        x86,
        report.metrics.expect("event plane reports metrics").sends,
    )
}

/// Fig 11 — raw algorithm over expanding hardware (boards sweep).
pub fn fig11(boards_sweep: &[usize], opts: &FigOpts, x86: &X86Cost) -> FigReport {
    let mut rows = Vec::new();
    for &boards in boards_sweep {
        // Full scale: panel sized to the boards' free threads, 1 state/thread.
        let full = scenarios::fig11_config(boards, opts.seed);
        let pred = predict(
            &Workload {
                n_hap: full.n_hap,
                n_mark: full.n_mark,
                n_targets: opts.full_targets,
                states_per_thread: 1,
                lane_width: 1, // paper-anchor regime: per-target pipeline
                kind: AppKind::Raw,
            },
            &ClusterConfig::with_boards(boards),
            &CostModel::default(),
        );
        let full_x86 = x86.raw_seconds(full.n_hap, full.n_mark, opts.full_targets);

        let (des_poets, des_x86, msgs) = if opts.skip_des {
            (None, None, None)
        } else {
            let cfg = des_panel_cfg(boards * opts.des_states_per_board, 0.01, opts.seed);
            let (p, x, m) = des_run_raw(&cfg, boards, 1, opts.des_targets);
            (Some(p), Some(x), Some(m))
        };
        rows.push(FigRow {
            x: boards.to_string(),
            panel: format!(
                "{}x{} ({})",
                full.n_hap,
                full.n_mark,
                fmt_count((full.n_hap * full.n_mark) as u64)
            ),
            des_speedup: des_poets.map(|p| des_x86.unwrap() / p),
            des_poets_s: des_poets,
            des_x86_s: des_x86,
            full_speedup: full_x86 / pred.seconds,
            full_poets_s: pred.seconds,
            full_x86_s: full_x86,
            messages: msgs,
        });
    }
    FigReport {
        title: "Fig 11 — raw event-driven algorithm over expanding hardware".into(),
        x_label: "boards".into(),
        rows,
    }
}

/// Fig 12 — soft-scheduling sweep on the full cluster.
pub fn fig12(spt_sweep: &[usize], opts: &FigOpts, x86: &X86Cost) -> FigReport {
    let mut rows = Vec::new();
    for &spt in spt_sweep {
        let full = scenarios::fig12_config(spt, opts.seed);
        let pred = predict(
            &Workload {
                n_hap: full.n_hap,
                n_mark: full.n_mark,
                n_targets: opts.full_targets,
                states_per_thread: spt,
                lane_width: 1, // paper-anchor regime: per-target pipeline
                kind: AppKind::Raw,
            },
            &ClusterConfig::poets_48(),
            &CostModel::default(),
        );
        let full_x86 = x86.raw_seconds(full.n_hap, full.n_mark, opts.full_targets);

        let (des_poets, des_x86, msgs) = if opts.skip_des {
            (None, None, None)
        } else {
            // Reduced: a 1-board cluster, panel sized to spt states/thread
            // over a fraction of its threads.
            let states = opts.des_states_per_board * spt;
            let cfg = des_panel_cfg(states, 0.01, opts.seed);
            let (p, x, m) = des_run_raw(&cfg, 1, spt, opts.des_targets);
            (Some(p), Some(x), Some(m))
        };
        rows.push(FigRow {
            x: spt.to_string(),
            panel: format!(
                "{}x{} ({})",
                full.n_hap,
                full.n_mark,
                fmt_count((full.n_hap * full.n_mark) as u64)
            ),
            des_speedup: des_poets.map(|p| des_x86.unwrap() / p),
            des_poets_s: des_poets,
            des_x86_s: des_x86,
            full_speedup: full_x86 / pred.seconds,
            full_poets_s: pred.seconds,
            full_x86_s: full_x86,
            messages: msgs,
        });
    }
    FigReport {
        title: "Fig 12 — soft-scheduling (states per hardware thread), 48 boards".into(),
        x_label: "states/thread".into(),
        rows,
    }
}

/// Fig 13 — linear interpolation over expanding hardware.
pub fn fig13(boards_sweep: &[usize], opts: &FigOpts, x86: &X86Cost) -> FigReport {
    let section = 10; // ratio 1/10: 1 HMM + 9 interpolation states
    let mut rows = Vec::new();
    for &boards in boards_sweep {
        let full = scenarios::fig13_config(boards, 1, opts.seed);
        let pred = predict(
            &Workload {
                n_hap: full.n_hap,
                n_mark: full.n_mark,
                n_targets: opts.full_targets,
                // One section VERTEX per thread (each holding `section`
                // panel states) — the paper's sub-49,152 configuration.
                states_per_thread: 1,
                lane_width: 1, // paper-anchor regime: per-target pipeline
                kind: AppKind::Interp { section },
            },
            &ClusterConfig::with_boards(boards),
            &CostModel::default(),
        );
        let anchors = annotated_markers(full.n_mark, full.annot_ratio).len();
        let full_x86 = x86.interp_seconds(full.n_hap, full.n_mark, anchors, opts.full_targets);

        let (des_poets, des_x86, msgs) = if opts.skip_des {
            (None, None, None)
        } else {
            let cfg = des_panel_cfg(boards * opts.des_states_per_board * 4, 0.1, opts.seed);
            let wl = SessionWorkload::synthetic(&cfg, opts.des_targets);
            let x = X86Cost::measure_interp_batch(wl.panel(), wl.targets());
            let report = ImputeSession::new(wl)
                .engine(EngineSpec::Interp)
                .boards(boards)
                .states_per_thread(1) // one section vertex per thread
                .run()
                .expect("interp plane on a shared annotation grid");
            (
                report.sim_seconds,
                Some(x),
                report.metrics.map(|m| m.sends),
            )
        };
        rows.push(FigRow {
            x: boards.to_string(),
            panel: format!(
                "{}x{} ({})",
                full.n_hap,
                full.n_mark,
                fmt_count((full.n_hap * full.n_mark) as u64)
            ),
            des_speedup: des_poets.map(|p| des_x86.unwrap() / p),
            des_poets_s: des_poets,
            des_x86_s: des_x86,
            full_speedup: full_x86 / pred.seconds,
            full_poets_s: pred.seconds,
            full_x86_s: full_x86,
            messages: msgs,
        });
    }
    FigReport {
        title: "Fig 13 — linear-interpolation algorithm over expanding hardware".into(),
        x_label: "boards".into(),
        rows,
    }
}

/// E4 — termination-detection overhead (paper §5.2: ~3 % of a step).
///
/// The ~3 % figure is a property of the paper's *operating point* (Fig 12,
/// ≥10 states/thread on the full cluster): the wave cost is fixed per step
/// while per-step work grows with panel size, so at reduced DES scale the
/// fraction is necessarily larger.  The report shows (a) the analytic
/// fraction at the paper's operating point, and (b) the DES trend across
/// growing panels converging toward it.
pub fn sync_overhead(opts: &FigOpts) -> String {
    let cost = CostModel::default();
    // (a) Paper operating point: Fig 12 optimum, analytic step breakdown.
    let full = scenarios::fig12_config(10, opts.seed);
    let pred = predict(
        &Workload {
            n_hap: full.n_hap,
            n_mark: full.n_mark,
            n_targets: opts.full_targets,
            states_per_thread: 10,
            lane_width: 1, // paper-anchor regime: per-target pipeline
            kind: AppKind::Raw,
        },
        &ClusterConfig::poets_48(),
        &cost,
    );
    let full_frac = pred.barrier_cycles as f64 / pred.step_cycles as f64;
    let mut out = format!(
        "E4 sync overhead at the paper's Fig 12 operating point (analytic): \
         barrier {} / step {} cycles = {:.1}% (paper: ~3%)\n\
         DES trend over growing panels (barrier fraction must fall):\n",
        pred.barrier_cycles,
        pred.step_cycles,
        full_frac * 100.0
    );
    // (b) DES trend: same cluster, growing panels.
    for mult in [1usize, 4, 16] {
        let cfg = des_panel_cfg(mult * opts.des_states_per_board, 0.01, opts.seed);
        let report = ImputeSession::new(SessionWorkload::synthetic(&cfg, opts.des_targets))
            .engine(EngineSpec::Event)
            .boards(1)
            .states_per_thread(4 * mult)
            .run()
            .expect("event plane is always available");
        let metrics = report.metrics.expect("event plane reports metrics");
        let frac = termination::overhead_fraction(
            metrics.mean_step_cycles() as u64,
            scenarios::THREADS_PER_BOARD,
            &cost,
        );
        out.push_str(&format!(
            "  {}x{} panel ({} states/thread): mean step {:.0} cycles, barrier {:.1}%\n",
            report.n_hap,
            report.n_mark,
            4 * mult,
            metrics.mean_step_cycles(),
            frac * 100.0
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> FigOpts {
        FigOpts {
            des_states_per_board: 48,
            des_targets: 6,
            full_targets: 1000,
            skip_des: false,
            seed: 5,
        }
    }

    fn fake_x86() -> X86Cost {
        X86Cost {
            dense_macs_per_s: 2e9,
            rank1_macs_per_s: 4e9,
        }
    }

    #[test]
    fn fig11_speedup_grows_with_boards() {
        let r = fig11(&[1, 8, 48], &FigOpts { skip_des: true, ..tiny_opts() }, &fake_x86());
        assert_eq!(r.rows.len(), 3);
        assert!(
            r.rows[2].full_speedup > r.rows[0].full_speedup,
            "Fig 11 shape: speedup must grow with boards ({} -> {})",
            r.rows[0].full_speedup,
            r.rows[2].full_speedup
        );
    }

    #[test]
    fn fig12_has_interior_optimum_region() {
        // The 270× peak is "for 10000 target haplotypes" — the optimum is
        // target-count dependent (the paper plots one curve per batch size),
        // so assert the shape at the paper's headline batch.
        let opts = FigOpts {
            skip_des: true,
            full_targets: 10_000,
            ..tiny_opts()
        };
        let r = fig12(&[1, 10, 40], &opts, &fake_x86());
        let s: Vec<f64> = r.rows.iter().map(|r| r.full_speedup).collect();
        // The paper's shape: 10 states/thread beats both extremes.
        assert!(s[1] > s[0], "optimum not above spt=1: {s:?}");
        assert!(s[1] > s[2], "optimum not above spt=40: {s:?}");
    }

    #[test]
    fn fig13_interp_beats_raw_on_the_same_panel() {
        // The reproducible core of Fig 13: on the SAME panel, the
        // interpolated event-driven algorithm is far faster than the raw one
        // (≈10× fewer messages, K instead of M pipeline columns).  The
        // paper's "~5 orders of magnitude vs similarly-optimised x86" is NOT
        // reproducible under any physically-consistent cost model — the
        // termination-wave floor alone (≈34k cycles × (K + T) steps ≈
        // 28 minutes-of-cluster-time per 10k targets) bounds the speedup ~3
        // orders below it; see EXPERIMENTS.md E3.
        use crate::imputation::analytic::{AppKind, Workload, predict};
        use crate::poets::costmodel::CostModel;
        let full = crate::workload::scenarios::fig13_config(48, 1, 0);
        let cluster = ClusterConfig::poets_48();
        // Same panel, same hardware: raw needs 10 HMM states per thread;
        // interp packs those 10 states into ONE section vertex per thread.
        let raw = predict(
            &Workload {
                n_hap: full.n_hap,
                n_mark: full.n_mark,
                n_targets: 10_000,
                states_per_thread: 10,
                lane_width: 1, // paper-anchor regime: per-target pipeline
                kind: AppKind::Raw,
            },
            &cluster,
            &CostModel::default(),
        );
        let itp = predict(
            &Workload {
                n_hap: full.n_hap,
                n_mark: full.n_mark,
                n_targets: 10_000,
                states_per_thread: 1,
                lane_width: 1, // paper-anchor regime: per-target pipeline
                kind: AppKind::Interp { section: 10 },
            },
            &cluster,
            &CostModel::default(),
        );
        assert!(
            itp.seconds * 3.0 < raw.seconds,
            "interp {}s vs raw {}s on the same panel",
            itp.seconds,
            raw.seconds
        );
    }

    #[test]
    fn fig13_speedup_grows_with_boards() {
        let opts = FigOpts { skip_des: true, ..tiny_opts() };
        let r = fig13(&[1, 8, 48], &opts, &fake_x86());
        assert!(
            r.rows[2].full_speedup > r.rows[0].full_speedup,
            "Fig 13 shape: {} -> {}",
            r.rows[0].full_speedup,
            r.rows[2].full_speedup
        );
    }

    #[test]
    fn des_plane_runs_and_wins() {
        let r = fig11(&[1], &tiny_opts(), &X86Cost::measure_default());
        let row = &r.rows[0];
        assert!(row.des_speedup.is_some());
        assert!(row.des_poets_s.unwrap() > 0.0);
        assert!(row.messages.unwrap() > 0);
    }

    #[test]
    fn report_renders_and_serialises() {
        let r = fig11(&[1, 2], &FigOpts { skip_des: true, ..tiny_opts() }, &fake_x86());
        let text = r.render();
        assert!(text.contains("Fig 11"));
        assert!(text.lines().count() >= 5);
        let j = r.to_json();
        assert!(j.render().contains("full_speedup"));
    }

    #[test]
    fn sync_overhead_in_paper_regime() {
        let report = sync_overhead(&tiny_opts());
        assert!(report.contains("E4 sync overhead"));
    }
}
