//! `bench topology` — the scenario lab's workload × topology × fault-model
//! sweep.
//!
//! Each sweep point runs one fixed imputation workload end-to-end on the DES
//! under a [`ScenarioSpec`] (heterogeneous link speeds, degraded links,
//! failed links with reroute), records the link-plane telemetry the NoC now
//! exposes, and cross-checks the measured cycles against
//! `imputation::analytic::predict_scenario`.  The cross-check is a **hard
//! gate**: any point whose analytic/DES ratio leaves [`GATE_BAND`] fails the
//! whole sweep (after the JSON artifact is written, so CI still archives the
//! offending numbers).
//!
//! The scenarios deliberately use small boards (a few threads each) so a
//! unit-scale panel spans several boards and actually exercises the link
//! plane — on full 1024-thread boards this workload would never leave
//! board 0.

use crate::imputation::analytic::{predict_scenario, AppKind, Workload as AWorkload};
use crate::poets::costmodel::CostModel;
use crate::poets::scenario::ScenarioSpec;
use crate::session::{EngineSpec, ImputeSession, Workload};
use crate::util::json::Json;
use crate::util::provenance;
use crate::util::table::{fmt_count, Table};
use crate::workload::panelgen::PanelConfig;

/// Schema tag on `BENCH_topology.json`.
pub const TOPOLOGY_SCHEMA: &str = "poets-impute/bench-topology/v1";

/// Allowed analytic/DES cycle ratio at every sweep point.  Forgiving by
/// design — the analytic model is a steady-state bottleneck bound, not a
/// simulator — but a point outside this band means one of the two planes
/// has stopped modelling the same machine.
pub const GATE_BAND: (f64, f64) = (0.25, 4.0);

/// Sweep configuration: one workload, many topologies.
#[derive(Clone, Debug)]
pub struct TopologyOpts {
    pub n_hap: usize,
    pub n_mark: usize,
    pub n_targets: usize,
    pub states_per_thread: usize,
    pub seed: u64,
    pub scenarios: Vec<ScenarioSpec>,
}

impl Default for TopologyOpts {
    fn default() -> Self {
        TopologyOpts {
            n_hap: 8,
            n_mark: 24,
            n_targets: 12,
            states_per_thread: 4,
            seed: 2023,
            scenarios: default_scenarios(),
        }
    }
}

impl TopologyOpts {
    /// The CI smoke shape: the default workload over the default scenario
    /// set (baseline + degraded + hotspot + failed link + the two fault-
    /// model cells — all six DES runs finish in well under a second).
    pub fn smoke() -> TopologyOpts {
        TopologyOpts::default()
    }

    /// The full sweep: the smoke set plus a wider cluster and a compound
    /// degraded-and-failed scenario, at a heavier target count.
    pub fn full() -> TopologyOpts {
        let mut o = TopologyOpts { n_targets: 24, ..TopologyOpts::default() };
        o.scenarios.push(scenario(
            "wide16",
            "boards=16,tiles=2,cores=1,threads=4",
        ));
        o.scenarios.push(scenario(
            "degraded-and-failed",
            "boards=8,tiles=2,cores=1,threads=4,bw=0.5,fail=0E",
        ));
        o
    }
}

/// Small boards (8 threads each) so the unit-scale panel spans ~6 of the 8
/// boards; see the module docs.
const SHAPE: &str = "boards=8,tiles=2,cores=1,threads=4";

fn scenario(name: &str, rest: &str) -> ScenarioSpec {
    ScenarioSpec::parse(&format!("name={name},{rest}"))
        .unwrap_or_else(|e| panic!("built-in scenario {name}: {e}"))
}

/// The default topology set: homogeneous baseline, globally slow
/// inter-board links, one congested hotspot link, one failed link, plus the
/// fault-model cells (a mid-run tile death under checkpoint/replay, and a
/// lossy pair of links exercising NACK/retransmit + duplicate suppression).
pub fn default_scenarios() -> Vec<ScenarioSpec> {
    vec![
        scenario("baseline", SHAPE),
        scenario("slow-links", &format!("{SHAPE},bw=0.25,lat=2")),
        scenario("hotspot-1E", &format!("{SHAPE},link=1E:bw=0.25")),
        scenario("failed-0E", &format!("{SHAPE},fail=0E")),
        scenario("failed-tile", &format!("{SHAPE},failtile=2.1@6,ckpt=4")),
        scenario(
            "lossy-links",
            &format!("{SHAPE},drop=0E:0.4@13,drop=1E:0.4@19,dup=2E:0.4@17"),
        ),
    ]
}

/// One sweep point's measurements.
#[derive(Clone, Debug)]
pub struct TopologyRow {
    pub scenario: ScenarioSpec,
    pub des_cycles: u64,
    pub des_steps: u64,
    pub max_link_utilisation: f64,
    pub link_events_total: u64,
    pub inter_board_copies: u64,
    pub rerouted_sends: u64,
    /// Fault-model telemetry (zero on fault-free cells).
    pub failed_tiles: u64,
    pub replayed_supersteps: u64,
    pub recovery_cycles: u64,
    pub dropped_events: u64,
    pub retransmits: u64,
    pub dup_events: u64,
    pub analytic_cycles: u64,
    /// analytic / DES.
    pub ratio: f64,
    pub gate_pass: bool,
}

/// A completed sweep.
#[derive(Clone, Debug)]
pub struct TopologyReport {
    pub opts: TopologyOpts,
    pub rows: Vec<TopologyRow>,
}

impl TopologyReport {
    pub fn gate_passed(&self) -> bool {
        self.rows.iter().all(|r| r.gate_pass)
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "scenario",
            "boards",
            "DES cycles",
            "steps",
            "max link util",
            "link events",
            "rerouted",
            "recovery",
            "drops",
            "analytic cycles",
            "ratio",
            "gate",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.scenario.name.clone(),
                r.scenario.boards.to_string(),
                fmt_count(r.des_cycles),
                fmt_count(r.des_steps),
                format!("{:.3}", r.max_link_utilisation),
                fmt_count(r.link_events_total),
                fmt_count(r.rerouted_sends),
                fmt_count(r.recovery_cycles),
                fmt_count(r.dropped_events),
                fmt_count(r.analytic_cycles),
                format!("{:.2}", r.ratio),
                if r.gate_pass { "ok".into() } else { "FAIL".into() },
            ]);
        }
        format!(
            "## topology sweep ({}x{} panel, {} targets, {} states/thread)\n{}analytic-vs-DES gate band: {:.2}..{:.2} — {}\n",
            self.opts.n_hap,
            self.opts.n_mark,
            self.opts.n_targets,
            self.opts.states_per_thread,
            t.render(),
            GATE_BAND.0,
            GATE_BAND.1,
            if self.gate_passed() { "PASS" } else { "FAIL" },
        )
    }

    /// The provenance-stamped `BENCH_topology.json` document.
    pub fn to_json(&self) -> Json {
        let mut run_config = Json::obj();
        run_config
            .set("n_hap", self.opts.n_hap)
            .set("n_mark", self.opts.n_mark)
            .set("n_targets", self.opts.n_targets)
            .set("states_per_thread", self.opts.states_per_thread)
            .set("seed", self.opts.seed)
            .set("gate_band", Json::Arr(vec![Json::from(GATE_BAND.0), Json::from(GATE_BAND.1)]));
        let mut doc = Json::obj();
        provenance::stamp(&mut doc, TOPOLOGY_SCHEMA, run_config);
        let mut rows = Json::Arr(Vec::new());
        for r in &self.rows {
            let mut o = Json::obj();
            o.set("scenario", r.scenario.to_json())
                .set("des_cycles", r.des_cycles)
                .set("des_steps", r.des_steps)
                .set("max_link_utilisation", r.max_link_utilisation)
                .set("link_events_total", r.link_events_total)
                .set("inter_board_copies", r.inter_board_copies)
                .set("rerouted_sends", r.rerouted_sends)
                .set("failed_tiles", r.failed_tiles)
                .set("replayed_supersteps", r.replayed_supersteps)
                .set("recovery_cycles", r.recovery_cycles)
                .set("dropped_events", r.dropped_events)
                .set("retransmits", r.retransmits)
                .set("dup_events", r.dup_events)
                .set("analytic_cycles", r.analytic_cycles)
                .set("analytic_vs_des_ratio", r.ratio)
                .set("gate_pass", r.gate_pass);
            rows.push(o);
        }
        doc.set("rows", rows).set("gate_passed", self.gate_passed());
        doc
    }
}

/// Run the sweep: every scenario gets the same workload and seed, so rows
/// differ only by topology.  Errors (an invalid spec, an engine failure)
/// abort the sweep; a *gate* failure does not — it is recorded per row and
/// surfaced by [`TopologyReport::gate_passed`], so the caller can archive
/// the artifact before failing.
pub fn run(opts: TopologyOpts) -> Result<TopologyReport, String> {
    let pcfg = PanelConfig {
        n_hap: opts.n_hap,
        n_mark: opts.n_mark,
        maf: 0.2,
        annot_ratio: 0.2,
        seed: opts.seed,
        ..PanelConfig::default()
    };
    let cost = CostModel::default();
    let mut rows = Vec::with_capacity(opts.scenarios.len());
    for spec in &opts.scenarios {
        spec.validate()?;
        let wl = Workload::synthetic(&pcfg, opts.n_targets);
        let report = ImputeSession::new(wl)
            .engine(EngineSpec::Event)
            .scenario(spec.clone())
            .states_per_thread(opts.states_per_thread)
            .run()
            .map_err(|e| format!("scenario {}: {e}", spec.name))?;
        let m = report
            .metrics
            .ok_or_else(|| format!("scenario {}: event plane returned no metrics", spec.name))?;
        let pred = predict_scenario(
            &AWorkload {
                n_hap: opts.n_hap,
                n_mark: opts.n_mark,
                n_targets: opts.n_targets,
                states_per_thread: opts.states_per_thread,
                // The session runs all targets as one batch.
                lane_width: opts.n_targets,
                kind: AppKind::Raw,
            },
            spec,
            &cost,
        );
        let ratio = if m.sim_cycles == 0 {
            f64::INFINITY
        } else {
            pred.total_cycles as f64 / m.sim_cycles as f64
        };
        rows.push(TopologyRow {
            scenario: spec.clone(),
            des_cycles: m.sim_cycles,
            des_steps: m.steps,
            max_link_utilisation: m.max_link_utilisation(),
            link_events_total: m.link_events_total,
            inter_board_copies: m.inter_board_copies,
            rerouted_sends: m.rerouted_sends,
            failed_tiles: m.failed_tiles,
            replayed_supersteps: m.replayed_supersteps,
            recovery_cycles: m.recovery_cycles,
            dropped_events: m.dropped_events,
            retransmits: m.retransmits,
            dup_events: m.dup_events,
            analytic_cycles: pred.total_cycles,
            ratio,
            gate_pass: (GATE_BAND.0..=GATE_BAND.1).contains(&ratio),
        });
    }
    Ok(TopologyReport { opts, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_sweep_passes_the_gate_and_exercises_links() {
        let report = run(TopologyOpts::smoke()).expect("sweep runs");
        assert!(report.rows.len() >= 3, "need >= 3 topologies");
        assert!(
            report.gate_passed(),
            "analytic-vs-DES gate failed:\n{}",
            report.render()
        );
        assert!(
            report.rows.iter().any(|r| r.scenario.is_degraded()),
            "sweep must include a degraded topology"
        );
        let failed = report
            .rows
            .iter()
            .find(|r| !r.scenario.failed.is_empty())
            .expect("sweep must include a failed-link topology");
        assert!(failed.rerouted_sends > 0, "failed link must force reroutes");
        for r in &report.rows {
            assert!(r.link_events_total > 0, "{}: no link traffic", r.scenario.name);
            assert!(r.inter_board_copies > 0);
            assert!(
                (0.0..=1.0).contains(&r.max_link_utilisation),
                "{}: utilisation {} out of [0,1]",
                r.scenario.name,
                r.max_link_utilisation
            );
        }
        // Globally degraded links must slow the DES relative to baseline.
        let cycles = |name: &str| {
            report.rows.iter().find(|r| r.scenario.name == name).unwrap().des_cycles
        };
        assert!(cycles("slow-links") > cycles("baseline"));
        // Fault-model cells: the tile death must actually fire, replay from
        // the checkpoint, and charge recovery — inside the same gate band.
        let ft = report
            .rows
            .iter()
            .find(|r| r.scenario.name == "failed-tile")
            .expect("sweep must include a failed-tile cell");
        assert_eq!(ft.failed_tiles, 1);
        assert!(ft.replayed_supersteps > 0, "death at step 6 with ckpt=4 replays");
        assert!(ft.recovery_cycles > 0);
        assert!(ft.gate_pass, "failed-tile cell left the gate band: {}", ft.ratio);
        assert!(ft.des_cycles > cycles("baseline"), "recovery is not free");
        // Lossy cell: drops are NACKed and retransmitted, dups suppressed.
        let lossy = report
            .rows
            .iter()
            .find(|r| r.scenario.name == "lossy-links")
            .expect("sweep must include a lossy-links cell");
        assert!(lossy.dropped_events > 0);
        assert_eq!(lossy.retransmits, lossy.dropped_events, "every drop retransmits");
        assert!(lossy.dup_events > 0);
        assert!(lossy.gate_pass, "lossy cell left the gate band: {}", lossy.ratio);
    }

    #[test]
    fn artifact_is_provenance_stamped_and_self_describing() {
        let report = run(TopologyOpts::smoke()).expect("sweep runs");
        let j = report.to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(TOPOLOGY_SCHEMA));
        assert!(j.get("git_commit").is_some());
        assert!(j.get("run_config").and_then(|c| c.get("n_targets")).is_some());
        let rows = j.get("rows").and_then(Json::as_arr).expect("rows");
        assert_eq!(rows.len(), report.rows.len());
        for r in rows {
            assert!(r.get("max_link_utilisation").and_then(Json::as_f64).is_some());
            assert!(r.get("analytic_vs_des_ratio").and_then(Json::as_f64).is_some());
            // The scenario echo must itself round-trip through the parser.
            let echo = r.get("scenario").expect("scenario echo");
            assert!(ScenarioSpec::from_json(echo).is_ok());
        }
        assert_eq!(j.get("gate_passed"), Some(&Json::Bool(true)));
    }
}
