//! Genetic-map generation — inter-marker genetic distances `d_m`.
//!
//! GBC technology picks marker loci for an even physical distribution, but the
//! *genetic* distances between adjacent pairs differ slightly (paper §3.2).
//! The paper draws them from "a randomized uniform distribution seeded from
//! HapMap3 data"; we do the same with a configurable uniform range whose
//! default is picked so τ lands in the regime genuine panels see.

use crate::util::rng::Rng;

/// Uniform-range genetic-map model.
#[derive(Clone, Copy, Debug)]
pub struct GenMapConfig {
    /// Lower bound of the uniform inter-marker distance (Morgans).
    pub d_lo: f64,
    /// Upper bound.
    pub d_hi: f64,
}

impl Default for GenMapConfig {
    fn default() -> Self {
        // HapMap3-like: ~36 Morgans over ~1.4M sampled markers genome-wide
        // gives a mean adjacent-marker distance of ~2.6e-5 M.  Benchmark
        // panels here are much denser than HapMap3 in markers-per-haplotype
        // (aspect ratio 100:1 at small H), so we scale the per-step distance
        // down a decade to keep τ per transition in the strongly-linked
        // regime (τ ~ 1e-2..1e-1) that eq. (2) assumes — otherwise the chain
        // recombines every step and imputation signal vanishes for any model.
        GenMapConfig {
            d_lo: 5e-7,
            d_hi: 5e-6,
        }
    }
}

/// Generate `n_mark` genetic distances; `d[0] = 0` (no left neighbour).
pub fn generate(cfg: &GenMapConfig, n_mark: usize, rng: &mut Rng) -> Vec<f64> {
    assert!(cfg.d_lo > 0.0 && cfg.d_lo < cfg.d_hi, "bad distance range");
    let mut d = Vec::with_capacity(n_mark);
    d.push(0.0);
    for _ in 1..n_mark {
        d.push(rng.uniform(cfg.d_lo, cfg.d_hi));
    }
    d
}

/// Total genetic length of a map (sum of distances).
pub fn total_length(d: &[f64]) -> f64 {
    d.iter().sum()
}

/// Cumulative genetic position of every marker (position[0] = 0).
pub fn positions(d: &[f64]) -> Vec<f64> {
    let mut pos = Vec::with_capacity(d.len());
    let mut acc = 0.0;
    for &x in d {
        acc += x;
        pos.push(acc);
    }
    pos
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_distance_zero_rest_in_range() {
        let cfg = GenMapConfig::default();
        let mut rng = Rng::new(1);
        let d = generate(&cfg, 1000, &mut rng);
        assert_eq!(d[0], 0.0);
        assert!(d[1..].iter().all(|&x| x >= cfg.d_lo && x < cfg.d_hi));
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = GenMapConfig::default();
        let a = generate(&cfg, 100, &mut Rng::new(5));
        let b = generate(&cfg, 100, &mut Rng::new(5));
        assert_eq!(a, b);
    }

    #[test]
    fn mean_near_midpoint() {
        let cfg = GenMapConfig::default();
        let mut rng = Rng::new(2);
        let d = generate(&cfg, 100_000, &mut rng);
        let mean = total_length(&d) / (d.len() - 1) as f64;
        let mid = (cfg.d_lo + cfg.d_hi) / 2.0;
        assert!((mean - mid).abs() / mid < 0.02, "mean={mean} mid={mid}");
    }

    #[test]
    fn positions_monotone() {
        let cfg = GenMapConfig::default();
        let mut rng = Rng::new(3);
        let d = generate(&cfg, 500, &mut rng);
        let pos = positions(&d);
        assert_eq!(pos[0], 0.0);
        assert!(pos.windows(2).all(|w| w[1] > w[0]));
        assert!((pos.last().unwrap() - total_length(&d)).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "bad distance range")]
    fn rejects_inverted_range() {
        generate(
            &GenMapConfig { d_lo: 1.0, d_hi: 0.5 },
            10,
            &mut Rng::new(0),
        );
    }
}
