//! Reference-panel and target-haplotype generation — paper §6.2.
//!
//! Panels are diallelic with a configurable overall minor-allele frequency
//! (5 % is "widely regarded as the cut off for genotype estimation"); every
//! column is kept polymorphic (a monomorphic column carries no imputation
//! signal and genuine GWAS chips do not type such sites).
//!
//! Targets are generated as *mosaics* of the reference haplotypes — exactly
//! the generative process the Li & Stephens model assumes: copy a random
//! reference row, switch rows with probability τ_m at each step, flip alleles
//! at the model error rate.  The truth is retained so accuracy can be scored
//! after masking.

use crate::model::panel::{ReferencePanel, TargetHaplotype};
use crate::model::params::ModelParams;
use crate::util::rng::Rng;

use super::genmap::{self, GenMapConfig};

/// Panel + target generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct PanelConfig {
    pub n_hap: usize,
    pub n_mark: usize,
    /// Overall minor-allele frequency (paper: 0.05).
    pub maf: f64,
    /// Target:reference marker ratio (paper: 1/100 raw, 1/10 interp).
    pub annot_ratio: f64,
    /// Genetic-map model.
    pub genmap: GenMapConfig,
    /// Model constants used for mosaic generation.
    pub params: ModelParams,
    pub seed: u64,
}

impl Default for PanelConfig {
    fn default() -> Self {
        PanelConfig {
            n_hap: 64,
            n_mark: 128,
            maf: 0.05,
            annot_ratio: 0.01,
            genmap: GenMapConfig::default(),
            params: ModelParams::default(),
            seed: 0,
        }
    }
}

/// A generated target: the full truth (for scoring) plus the masked
/// observation vector actually given to the imputation engines.
#[derive(Clone, Debug)]
pub struct TargetCase {
    pub truth: Vec<u8>,
    pub masked: TargetHaplotype,
}

/// Generate a reference panel per the paper's recipe.
pub fn generate_panel(cfg: &PanelConfig) -> ReferencePanel {
    assert!(cfg.maf > 0.0 && cfg.maf <= 0.5, "maf must be in (0, 0.5]");
    let mut rng = Rng::new(cfg.seed);
    let gen_dist = genmap::generate(&cfg.genmap, cfg.n_mark, &mut rng);
    let mut alleles = vec![0u8; cfg.n_hap * cfg.n_mark];
    for m in 0..cfg.n_mark {
        // Bernoulli(maf) per cell, then force polymorphism: a column with no
        // minor allele (or all minor) is re-anchored by flipping one row.
        let mut ones = 0usize;
        for h in 0..cfg.n_hap {
            if rng.chance(cfg.maf) {
                alleles[h * cfg.n_mark + m] = 1;
                ones += 1;
            }
        }
        if ones == 0 {
            let h = rng.range(0, cfg.n_hap);
            alleles[h * cfg.n_mark + m] = 1;
        } else if ones == cfg.n_hap {
            let h = rng.range(0, cfg.n_hap);
            alleles[h * cfg.n_mark + m] = 0;
        }
    }
    ReferencePanel::new(cfg.n_hap, cfg.n_mark, alleles, gen_dist)
}

/// Annotated marker indices for a given ratio: a regular grid (chips type
/// evenly spaced loci) that always includes the first and last markers so
/// linear interpolation never extrapolates.
pub fn annotated_markers(n_mark: usize, annot_ratio: f64) -> Vec<usize> {
    assert!(annot_ratio > 0.0 && annot_ratio <= 1.0);
    let stride = (1.0 / annot_ratio).round().max(1.0) as usize;
    let mut marks: Vec<usize> = (0..n_mark).step_by(stride).collect();
    if *marks.last().unwrap() != n_mark - 1 {
        marks.push(n_mark - 1);
    }
    marks
}

/// Generate `count` mosaic targets with truth retained.
pub fn generate_targets(
    panel: &ReferencePanel,
    cfg: &PanelConfig,
    count: usize,
    rng: &mut Rng,
) -> Vec<TargetCase> {
    let marks = annotated_markers(panel.n_mark(), cfg.annot_ratio);
    (0..count)
        .map(|_| {
            let truth = mosaic_haplotype(panel, &cfg.params, rng);
            let mut obs = vec![-1i8; panel.n_mark()];
            for &m in &marks {
                obs[m] = truth[m] as i8;
            }
            TargetCase {
                truth,
                masked: TargetHaplotype::new(obs),
            }
        })
        .collect()
}

/// Draw one haplotype from the Li & Stephens generative process.
fn mosaic_haplotype(panel: &ReferencePanel, params: &ModelParams, rng: &mut Rng) -> Vec<u8> {
    let h_n = panel.n_hap();
    let mut row = rng.range(0, h_n);
    let mut out = Vec::with_capacity(panel.n_mark());
    for m in 0..panel.n_mark() {
        if m > 0 {
            let tau = params.tau(panel.gen_dist(m), h_n);
            if rng.chance(tau) {
                row = rng.range(0, h_n); // recombination: jump anywhere
            }
        }
        let mut a = panel.allele(row, m);
        if rng.chance(params.err) {
            a ^= 1; // mutation/genotyping error
        }
        out.push(a);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panel_shape_and_determinism() {
        let cfg = PanelConfig {
            n_hap: 20,
            n_mark: 50,
            seed: 3,
            ..PanelConfig::default()
        };
        let a = generate_panel(&cfg);
        let b = generate_panel(&cfg);
        assert_eq!(a.n_hap(), 20);
        assert_eq!(a.n_mark(), 50);
        for h in 0..20 {
            assert_eq!(a.haplotype(h), b.haplotype(h));
        }
    }

    #[test]
    fn every_column_polymorphic() {
        let cfg = PanelConfig {
            n_hap: 8,
            n_mark: 200,
            maf: 0.05,
            seed: 4,
            ..PanelConfig::default()
        };
        let p = generate_panel(&cfg);
        for m in 0..p.n_mark() {
            let f = p.allele_freq(m);
            assert!(f > 0.0 && f < 1.0, "column {m} monomorphic");
        }
    }

    #[test]
    fn overall_maf_near_target() {
        let cfg = PanelConfig {
            n_hap: 100,
            n_mark: 1000,
            maf: 0.05,
            seed: 5,
            ..PanelConfig::default()
        };
        let p = generate_panel(&cfg);
        let mean_freq: f64 =
            (0..p.n_mark()).map(|m| p.allele_freq(m)).sum::<f64>() / p.n_mark() as f64;
        assert!((mean_freq - 0.05).abs() < 0.01, "maf={mean_freq}");
    }

    #[test]
    fn annotated_grid_includes_ends() {
        let marks = annotated_markers(1000, 0.01);
        assert_eq!(marks[0], 0);
        assert_eq!(*marks.last().unwrap(), 999);
        // Ratio 1/100 over 1000 markers: 10 grid points + forced end.
        assert_eq!(marks.len(), 11);
        assert!(marks.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn annotated_ratio_one_is_every_marker() {
        let marks = annotated_markers(17, 1.0);
        assert_eq!(marks, (0..17).collect::<Vec<_>>());
    }

    #[test]
    fn targets_masked_at_grid_only() {
        let cfg = PanelConfig {
            n_hap: 16,
            n_mark: 100,
            annot_ratio: 0.1,
            seed: 6,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let mut rng = Rng::new(7);
        let cases = generate_targets(&panel, &cfg, 3, &mut rng);
        let marks = annotated_markers(100, 0.1);
        for case in &cases {
            assert_eq!(case.truth.len(), 100);
            for m in 0..100 {
                if marks.contains(&m) {
                    assert_eq!(case.masked.obs[m], case.truth[m] as i8);
                } else {
                    assert_eq!(case.masked.obs[m], -1);
                }
            }
        }
    }

    #[test]
    fn mosaic_targets_resemble_panel() {
        // A mosaic hap should mostly agree with *some* panel row locally;
        // sanity-check global allele stats are panel-like.
        let cfg = PanelConfig {
            n_hap: 30,
            n_mark: 400,
            maf: 0.05,
            seed: 8,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let mut rng = Rng::new(9);
        let cases = generate_targets(&panel, &cfg, 5, &mut rng);
        for case in cases {
            let freq: f64 = case.truth.iter().map(|&a| a as f64).sum::<f64>() / 400.0;
            assert!(freq < 0.15, "mosaic allele freq {freq} implausible");
        }
    }
}
