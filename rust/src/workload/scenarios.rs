//! Paper-shaped workload sizing.
//!
//! §6.2: "The aspect ratio of the reference panels was chosen based on
//! haplotypes/markers in existing GWAS, assuming genotyping technology chooses
//! markers for a uniform distribution and noting that chromosome 1 accounts
//! for approximately 8 % of the whole human genome."
//!
//! HapMap3-like numbers: ~1,000 haplotypes over ~1.4 M genome-wide markers →
//! chromosome 1 carries ~112k markers, i.e. roughly 100 markers per haplotype.
//! We keep that markers-per-haplotype ratio as panels scale.

use super::panelgen::PanelConfig;

/// Markers-per-haplotype aspect ratio (see module docs).
pub const MARKERS_PER_HAP: f64 = 100.0;

/// POETS hardware-thread count per FPGA board (16 tiles × 4 cores × 16 threads).
pub const THREADS_PER_BOARD: usize = 1024;

/// Full-cluster thread count (48 boards).
pub const FULL_CLUSTER_THREADS: usize = 48 * THREADS_PER_BOARD;

/// Split a state budget into (n_hap, n_mark) at the paper's aspect ratio.
///
/// `n_hap · n_mark ≈ n_states` with `n_mark / n_hap ≈ MARKERS_PER_HAP`,
/// both at least 2.
pub fn aspect_for_states(n_states: usize) -> (usize, usize) {
    aspect_for_states_ratio(n_states, MARKERS_PER_HAP)
}

/// As [`aspect_for_states`] with an explicit markers-per-haplotype ratio.
/// DES-feasible sweeps use a squarer aspect (e.g. 10:1) so the haplotype
/// fan-in stays representative at small state counts; full-scale analytic
/// sweeps keep the paper's 100:1.
pub fn aspect_for_states_ratio(n_states: usize, markers_per_hap: f64) -> (usize, usize) {
    assert!(n_states >= 4, "panel needs at least 2x2 states");
    let n_hap = ((n_states as f64 / markers_per_hap).sqrt().round() as usize).max(2);
    let n_mark = (n_states / n_hap).max(2);
    (n_hap, n_mark)
}

/// Panel config sized for `boards` FPGA boards at one state per hardware
/// thread (the Fig 11 regime: "reference panel sizes less than the 49,152
/// hardware threads available").
pub fn fig11_config(boards: usize, seed: u64) -> PanelConfig {
    let (n_hap, n_mark) = aspect_for_states(boards * THREADS_PER_BOARD);
    PanelConfig {
        n_hap,
        n_mark,
        maf: 0.05,
        annot_ratio: 0.01,
        seed,
        ..PanelConfig::default()
    }
}

/// Panel config for the Fig 12 soft-scheduling sweep: the full cluster with
/// `states_per_thread` panel states per hardware thread.
pub fn fig12_config(states_per_thread: usize, seed: u64) -> PanelConfig {
    let (n_hap, n_mark) = aspect_for_states(FULL_CLUSTER_THREADS * states_per_thread);
    PanelConfig {
        n_hap,
        n_mark,
        maf: 0.05,
        annot_ratio: 0.01,
        seed,
        ..PanelConfig::default()
    }
}

/// Panel config for Fig 13 (linear interpolation): ratio 1/10, each thread
/// governing one HMM state + 9 interpolation states per section.
pub fn fig13_config(boards: usize, sections_per_thread: usize, seed: u64) -> PanelConfig {
    let states = boards * THREADS_PER_BOARD * sections_per_thread * 10;
    let (n_hap, n_mark) = aspect_for_states(states);
    PanelConfig {
        n_hap,
        n_mark,
        maf: 0.05,
        annot_ratio: 0.1,
        seed,
        ..PanelConfig::default()
    }
}

/// Scale a paper-shaped config down by `factor` in state count (keeping the
/// aspect ratio) so CI-sized runs keep the figure's *shape*.
pub fn scaled(cfg: &PanelConfig, factor: usize) -> PanelConfig {
    assert!(factor >= 1);
    let states = (cfg.n_hap * cfg.n_mark / factor).max(4);
    let (n_hap, n_mark) = aspect_for_states(states);
    PanelConfig {
        n_hap,
        n_mark,
        ..*cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aspect_ratio_held() {
        let (h, m) = aspect_for_states(49_152);
        assert!(h >= 2 && m >= 2);
        let ratio = m as f64 / h as f64;
        assert!(
            (ratio / MARKERS_PER_HAP - 1.0).abs() < 0.35,
            "ratio {ratio} too far from {MARKERS_PER_HAP}"
        );
        let states = h * m;
        assert!(
            (states as f64 / 49_152.0 - 1.0).abs() < 0.1,
            "states {states}"
        );
    }

    #[test]
    fn aspect_small_panels_clamped() {
        let (h, m) = aspect_for_states(4);
        assert!(h >= 2 && m >= 2);
    }

    #[test]
    fn fig11_scales_with_boards() {
        let one = fig11_config(1, 0);
        let full = fig11_config(48, 0);
        assert!(full.n_hap * full.n_mark > 40 * one.n_hap * one.n_mark);
        assert_eq!(one.annot_ratio, 0.01);
    }

    #[test]
    fn fig12_scales_with_softsched() {
        let a = fig12_config(1, 0);
        let b = fig12_config(10, 0);
        let fa = a.n_hap * a.n_mark;
        let fb = b.n_hap * b.n_mark;
        assert!(fb > 8 * fa && fb < 12 * fa, "{fa} -> {fb}");
    }

    #[test]
    fn fig13_ratio_is_one_tenth() {
        let cfg = fig13_config(2, 1, 0);
        assert_eq!(cfg.annot_ratio, 0.1);
        assert!(cfg.n_hap * cfg.n_mark >= 2 * THREADS_PER_BOARD * 10 * 9 / 10);
    }

    #[test]
    fn scaled_preserves_other_fields() {
        let cfg = fig11_config(48, 7);
        let s = scaled(&cfg, 64);
        assert_eq!(s.seed, 7);
        assert_eq!(s.annot_ratio, cfg.annot_ratio);
        assert!(s.n_hap * s.n_mark <= cfg.n_hap * cfg.n_mark / 32);
    }
}
