//! Synthetic workload generation — the paper's §6.2 recipe.
//!
//! The paper evaluates on synthetic reference panels "generated using features
//! from genuine GWAS": diallelic data at 5 % overall minor-allele frequency,
//! genetic distances drawn from a randomized uniform distribution seeded from
//! HapMap3 scale, a 1/100 (raw) or 1/10 (interp) target:reference marker
//! ratio, and aspect ratios following haplotype/marker counts in existing
//! GWAS (chromosome 1 ≈ 8 % of the genome).  This module reproduces exactly
//! that generation process.

pub mod genmap;
pub mod panelgen;
pub mod scenarios;

pub use panelgen::{PanelConfig, TargetCase, generate_panel, generate_targets};
