//! DES trace capture and the `poets-impute/trace/v1` JSONL schema.
//!
//! A trace is a bounded ring of per-superstep records. Capture happens in
//! `poets::desim`: each `TileShard` accumulates scratch counters during its
//! (possibly parallel) deliver phase, and the simulator's deterministic
//! serial shard reduce folds them into one [`StepRecord`] per superstep —
//! shard order is tile order, so the record is bit-identical for any
//! `SimConfig::threads` value.
//!
//! # `poets-impute/trace/v1` (JSONL)
//!
//! Line 1 is a provenance-stamped header object:
//!
//! ```json
//! {"schema":"poets-impute/trace/v1","git_commit":"...","run_config":{...},
//!  "kind":"header","n_tiles":64,"col_stride":8,"max_steps":4096,
//!  "segments":1,"total_steps":123,"dropped_steps":0,"steps_recorded":123}
//! ```
//!
//! Every following line is one superstep (`kind:"step"`), with per-tile
//! samples packed as `[tile, queue_hw, copies, lanes, col_min, col_max]`
//! arrays (only tiles that delivered at least one event appear) and
//! per-inter-board-link samples packed as `[link, events, busy, queue_hw]`
//! arrays (only links that carried traffic appear; link id = board·4 + dir,
//! dir E/W/N/S = 0..3):
//!
//! ```json
//! {"kind":"step","segment":0,"step":7,"t0":700,"t1":800,"busy_tiles":2,
//!  "copies":12,"lanes":96,"queue_hw":5,"col_min":3,"col_max":4,
//!  "link_events":3,"link_busy":33,
//!  "tiles":[[0,5,8,64,3,4],[9,2,4,32,3,3]],"links":[[0,3,33,2]]}
//! ```
//!
//! Column spans use `null` for "unattributed" (the in-memory sentinel is
//! [`NO_COL`]). The parser is strict: any malformed line fails the whole
//! file with its line number — no silent skipping.  When the ring bound
//! evicted records, the header says so explicitly (`dropped_steps` count
//! plus a `truncated` flag) — the no-silent-caps rule.

use std::collections::VecDeque;

use crate::util::json::Json;
use crate::util::stats::percentile;
use crate::util::table::{fmt_count, Table};

use super::span::log2_bucket;

/// Schema tag on the header line of a trace JSONL file.
pub const TRACE_SCHEMA: &str = "poets-impute/trace/v1";

/// In-memory sentinel column meaning "no column attribution".
pub const NO_COL: u32 = u32::MAX;

/// Maximum rows printed in the per-tile utilisation table before the
/// summary switches to an explicit "(+N more)" note.
const SUMMARY_TILE_ROWS: usize = 32;

/// Maximum rows in the per-link utilisation table (same honesty rule).
const SUMMARY_LINK_ROWS: usize = 16;

/// Links named in the "top congested links" line.
const TOP_CONGESTED_LINKS: usize = 4;

/// What the simulator records when tracing is enabled
/// (`SimConfig::trace = Some(TraceConfig { .. })`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceConfig {
    /// Ring capacity: at most this many most-recent superstep records are
    /// retained. Older records are dropped *and counted* — never silently
    /// lost. `0` means unbounded.
    pub max_steps: usize,
    /// Vertex-id stride of one wavefront column: `vertex / col_stride` is
    /// the column index. Engines fill this from the panel shape (both the
    /// raw and interp planes lay vertices out column-major); `None`
    /// disables column-span attribution.
    pub col_stride: Option<u32>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { max_steps: 4096, col_stride: None }
    }
}

/// One tile's delivery activity within one superstep. Only tiles that
/// ingested at least one event are sampled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileSample {
    pub tile: u32,
    /// Queue-depth high-water: events pending at this tile when the
    /// superstep's deliver phase began.
    pub queue_hw: u32,
    /// Message copies delivered at this tile this superstep.
    pub copies: u64,
    /// SoA wave lanes delivered (copies weighted by occupied lane count).
    pub lanes: u64,
    /// Wavefront column span touched ([`NO_COL`]/[`NO_COL`] when
    /// unattributed, i.e. `TraceConfig::col_stride` was `None`).
    pub col_min: u32,
    pub col_max: u32,
}

/// One inter-board link's activity within one superstep. Only links that
/// carried at least one event crossing are sampled. Captured by the NoC
/// during the *serial* dispatch phase, so the samples are thread-count
/// deterministic by construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinkSample {
    /// Link id: `board * 4 + dir` (dir E/W/N/S = 0..3).
    pub link: u32,
    /// Event crossings serialised onto this link this superstep.
    pub events: u32,
    /// Cycles this link spent busy serialising those crossings.
    pub busy: u64,
    /// Queue high-water: deepest backlog (in whole serialisation slots)
    /// any crossing found queued ahead of it this superstep.
    pub queue_hw: u32,
}

impl LinkSample {
    /// Human name, e.g. link 13 → `"3N"` (board 3, north).
    pub fn name(link: u32) -> String {
        // Direction order matches `poets::noc::Dir`: E, W, N, S.
        let dir = ['E', 'W', 'N', 'S'][(link % 4) as usize];
        format!("{}{}", link / 4, dir)
    }
}

/// One superstep's merged record. `tiles` is in ascending tile order
/// (shard order == tile order in the serial reduce); `links` is in
/// ascending link order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepRecord {
    /// Engine-run index for multi-batch / multi-window sessions: 0 within
    /// a single simulator run, bumped by [`RunTrace::absorb`].
    pub segment: u32,
    /// Superstep index within the segment.
    pub step: u64,
    /// Simulated-time span of this superstep, in cost-model cycles.
    pub t_start: u64,
    pub t_end: u64,
    /// Number of tiles that delivered at least one event.
    pub busy_tiles: u32,
    pub copies: u64,
    pub lanes: u64,
    /// Maximum per-tile queue-depth high-water this superstep.
    pub queue_hw: u32,
    pub col_min: u32,
    pub col_max: u32,
    /// Inter-board event crossings this superstep (sum over `links`).
    pub link_events: u64,
    /// Link-busy cycles this superstep (sum over `links`).
    pub link_busy: u64,
    pub tiles: Vec<TileSample>,
    pub links: Vec<LinkSample>,
}

/// A bounded, deterministic trace of one imputation run (possibly spanning
/// several engine runs — batches, windows — as distinct segments).
#[derive(Clone, Debug, PartialEq)]
pub struct RunTrace {
    pub n_tiles: u32,
    pub col_stride: Option<u32>,
    /// Ring bound carried from [`TraceConfig::max_steps`].
    pub max_steps: usize,
    /// Most-recent records, in (segment, step) order.
    pub steps: VecDeque<StepRecord>,
    /// Records evicted by the ring bound (oldest first).
    pub dropped_steps: u64,
    /// Supersteps observed: recorded + dropped.
    pub total_steps: u64,
    /// Engine runs folded into this trace.
    pub segments: u32,
}

impl RunTrace {
    pub fn new(cfg: TraceConfig, n_tiles: u32) -> RunTrace {
        RunTrace {
            n_tiles,
            col_stride: cfg.col_stride,
            max_steps: cfg.max_steps,
            steps: VecDeque::new(),
            dropped_steps: 0,
            total_steps: 0,
            segments: 1,
        }
    }

    fn enforce_bound(&mut self) {
        while self.max_steps > 0 && self.steps.len() > self.max_steps {
            self.steps.pop_front();
            self.dropped_steps += 1;
        }
    }

    /// Record one superstep, evicting the oldest record past the bound.
    pub fn push(&mut self, rec: StepRecord) {
        self.total_steps += 1;
        self.steps.push_back(rec);
        self.enforce_bound();
    }

    /// Fold a later engine run into this trace as fresh segments (batch
    /// loops and windowed/streamed runs produce one trace per engine run).
    pub fn absorb(&mut self, mut other: RunTrace) {
        let base = self.segments;
        for rec in &mut other.steps {
            rec.segment += base;
        }
        self.segments += other.segments;
        self.total_steps += other.total_steps;
        self.dropped_steps += other.dropped_steps;
        self.n_tiles = self.n_tiles.max(other.n_tiles);
        if self.col_stride.is_none() {
            self.col_stride = other.col_stride;
        }
        for rec in other.steps {
            self.steps.push_back(rec);
            self.enforce_bound();
        }
    }

    /// Render as `poets-impute/trace/v1` JSONL with a freshly
    /// provenance-stamped header. One compact line per recorded superstep;
    /// rendering is deterministic, so two bit-identical traces produce
    /// byte-identical files (given the same `run_config`).
    pub fn to_jsonl(&self, run_config: Json) -> String {
        let mut header = Json::obj();
        crate::util::provenance::stamp(&mut header, TRACE_SCHEMA, run_config);
        header
            .set("kind", "header")
            .set("n_tiles", u64::from(self.n_tiles))
            .set("col_stride", self.col_stride.map_or(Json::Null, |s| Json::Int(i64::from(s))))
            .set("max_steps", self.max_steps)
            .set("segments", u64::from(self.segments))
            .set("total_steps", self.total_steps)
            .set("dropped_steps", self.dropped_steps)
            .set("truncated", self.dropped_steps > 0)
            .set("steps_recorded", self.steps.len());
        let mut out = header.render();
        out.push('\n');
        for rec in &self.steps {
            out.push_str(&step_json(rec).render());
            out.push('\n');
        }
        out
    }
}

fn col_json(c: u32) -> Json {
    if c == NO_COL {
        Json::Null
    } else {
        Json::Int(i64::from(c))
    }
}

fn step_json(rec: &StepRecord) -> Json {
    let mut tiles = Json::Arr(Vec::new());
    for t in &rec.tiles {
        tiles.push(Json::Arr(vec![
            Json::Int(i64::from(t.tile)),
            Json::Int(i64::from(t.queue_hw)),
            Json::from(t.copies),
            Json::from(t.lanes),
            col_json(t.col_min),
            col_json(t.col_max),
        ]));
    }
    let mut links = Json::Arr(Vec::new());
    for l in &rec.links {
        links.push(Json::Arr(vec![
            Json::Int(i64::from(l.link)),
            Json::Int(i64::from(l.events)),
            Json::from(l.busy),
            Json::Int(i64::from(l.queue_hw)),
        ]));
    }
    let mut j = Json::obj();
    j.set("kind", "step")
        .set("segment", rec.segment as u64)
        .set("step", rec.step)
        .set("t0", rec.t_start)
        .set("t1", rec.t_end)
        .set("busy_tiles", rec.busy_tiles as u64)
        .set("copies", rec.copies)
        .set("lanes", rec.lanes)
        .set("queue_hw", rec.queue_hw as u64)
        .set("col_min", col_json(rec.col_min))
        .set("col_max", col_json(rec.col_max))
        .set("link_events", rec.link_events)
        .set("link_busy", rec.link_busy)
        .set("tiles", tiles)
        .set("links", links);
    j
}

/// A parsed trace file: the verbatim header object (provenance included)
/// plus the reconstructed [`RunTrace`]. [`TraceFile::render`] re-emits the
/// stored header, so `parse` → `render` round-trips byte-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceFile {
    pub header: Json,
    pub trace: RunTrace,
}

fn field_u64(j: &Json, key: &str, line: usize) -> Result<u64, String> {
    match j.get(key).and_then(Json::as_i64) {
        Some(v) if v >= 0 => Ok(v as u64),
        _ => Err(format!("line {line}: missing or invalid \"{key}\"")),
    }
}

fn field_col(j: &Json, key: &str, line: usize) -> Result<u32, String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(NO_COL),
        Some(v) => match v.as_i64() {
            Some(c) if (0..i64::from(u32::MAX)).contains(&c) => Ok(c as u32),
            _ => Err(format!("line {line}: invalid column \"{key}\"")),
        },
    }
}

fn arr_col(v: &Json, line: usize) -> Result<u32, String> {
    match v {
        Json::Null => Ok(NO_COL),
        _ => match v.as_i64() {
            Some(c) if (0..i64::from(u32::MAX)).contains(&c) => Ok(c as u32),
            _ => Err(format!("line {line}: invalid tile column entry")),
        },
    }
}

fn parse_tile(v: &Json, line: usize) -> Result<TileSample, String> {
    let Json::Arr(xs) = v else {
        return Err(format!("line {line}: tile sample is not an array"));
    };
    if xs.len() != 6 {
        return Err(format!("line {line}: tile sample has {} fields, want 6", xs.len()));
    }
    let int = |i: usize| -> Result<u64, String> {
        match xs[i].as_i64() {
            Some(v) if v >= 0 => Ok(v as u64),
            _ => Err(format!("line {line}: invalid tile sample field {i}")),
        }
    };
    Ok(TileSample {
        tile: int(0)? as u32,
        queue_hw: int(1)? as u32,
        copies: int(2)?,
        lanes: int(3)?,
        col_min: arr_col(&xs[4], line)?,
        col_max: arr_col(&xs[5], line)?,
    })
}

fn parse_link(v: &Json, line: usize) -> Result<LinkSample, String> {
    let Json::Arr(xs) = v else {
        return Err(format!("line {line}: link sample is not an array"));
    };
    if xs.len() != 4 {
        return Err(format!("line {line}: link sample has {} fields, want 4", xs.len()));
    }
    let int = |i: usize| -> Result<u64, String> {
        match xs[i].as_i64() {
            Some(v) if v >= 0 => Ok(v as u64),
            _ => Err(format!("line {line}: invalid link sample field {i}")),
        }
    };
    Ok(LinkSample {
        link: int(0)? as u32,
        events: int(1)? as u32,
        busy: int(2)?,
        queue_hw: int(3)? as u32,
    })
}

fn parse_step(j: &Json, line: usize) -> Result<StepRecord, String> {
    let tiles = match j.get("tiles") {
        Some(Json::Arr(xs)) => {
            xs.iter().map(|v| parse_tile(v, line)).collect::<Result<Vec<_>, _>>()?
        }
        _ => return Err(format!("line {line}: missing \"tiles\" array")),
    };
    let links = match j.get("links") {
        Some(Json::Arr(xs)) => {
            xs.iter().map(|v| parse_link(v, line)).collect::<Result<Vec<_>, _>>()?
        }
        _ => return Err(format!("line {line}: missing \"links\" array")),
    };
    Ok(StepRecord {
        segment: field_u64(j, "segment", line)? as u32,
        step: field_u64(j, "step", line)?,
        t_start: field_u64(j, "t0", line)?,
        t_end: field_u64(j, "t1", line)?,
        busy_tiles: field_u64(j, "busy_tiles", line)? as u32,
        copies: field_u64(j, "copies", line)?,
        lanes: field_u64(j, "lanes", line)?,
        queue_hw: field_u64(j, "queue_hw", line)? as u32,
        col_min: field_col(j, "col_min", line)?,
        col_max: field_col(j, "col_max", line)?,
        link_events: field_u64(j, "link_events", line)?,
        link_busy: field_u64(j, "link_busy", line)?,
        tiles,
        links,
    })
}

impl TraceFile {
    /// Strict `poets-impute/trace/v1` parser. Any malformed line — bad
    /// JSON, wrong schema, unknown `kind`, missing field, header/step
    /// count mismatch — rejects the whole file with its line number.
    pub fn parse(text: &str) -> Result<TraceFile, String> {
        let mut header: Option<Json> = None;
        let mut trace: Option<RunTrace> = None;
        for (i, raw) in text.lines().enumerate() {
            let line = i + 1;
            if raw.trim().is_empty() {
                return Err(format!("line {line}: blank line in trace"));
            }
            let j = Json::parse(raw).map_err(|e| format!("line {line}: {e}"))?;
            let kind = j
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("line {line}: missing \"kind\""))?
                .to_string();
            match kind.as_str() {
                "header" => {
                    if header.is_some() {
                        return Err(format!("line {line}: duplicate header"));
                    }
                    let schema = j.get("schema").and_then(Json::as_str);
                    if schema != Some(TRACE_SCHEMA) {
                        return Err(format!(
                            "line {line}: schema {:?} is not {TRACE_SCHEMA:?}",
                            schema.unwrap_or("<missing>")
                        ));
                    }
                    let col_stride = match j.get("col_stride") {
                        None | Some(Json::Null) => None,
                        Some(v) => match v.as_i64() {
                            Some(s) if s > 0 => Some(s as u32),
                            _ => return Err(format!("line {line}: invalid \"col_stride\"")),
                        },
                    };
                    trace = Some(RunTrace {
                        n_tiles: field_u64(&j, "n_tiles", line)? as u32,
                        col_stride,
                        max_steps: field_u64(&j, "max_steps", line)? as usize,
                        steps: VecDeque::new(),
                        dropped_steps: field_u64(&j, "dropped_steps", line)?,
                        total_steps: field_u64(&j, "total_steps", line)?,
                        segments: field_u64(&j, "segments", line)? as u32,
                    });
                    header = Some(j);
                }
                "step" => {
                    let Some(t) = trace.as_mut() else {
                        return Err(format!("line {line}: step record before header"));
                    };
                    t.steps.push_back(parse_step(&j, line)?);
                }
                other => return Err(format!("line {line}: unknown kind {other:?}")),
            }
        }
        let header = header.ok_or_else(|| "trace file is empty".to_string())?;
        let trace = trace.expect("trace present whenever header is");
        let declared = header
            .get("steps_recorded")
            .and_then(Json::as_usize)
            .ok_or_else(|| "line 1: missing \"steps_recorded\"".to_string())?;
        if declared != trace.steps.len() {
            return Err(format!(
                "header declares {declared} step records, file has {}",
                trace.steps.len()
            ));
        }
        Ok(TraceFile { header, trace })
    }

    /// Re-emit the file: stored header verbatim, then one line per step.
    pub fn render(&self) -> String {
        let mut out = self.header.render();
        out.push('\n');
        for rec in &self.trace.steps {
            out.push_str(&step_json(rec).render());
            out.push('\n');
        }
        out
    }
}

/// Aggregated per-link activity over the recorded window of a trace.
struct LinkAgg {
    link: u32,
    /// Supersteps in which this link carried at least one crossing.
    busy_steps: u64,
    events: u64,
    busy: u64,
    queue_hw: u32,
}

/// Fold every step's link samples into per-link totals, plus the recorded
/// simulated span (sum of step durations) for utilisation denominators.
/// Returns links in descending (busy, events) order.
fn aggregate_links(t: &RunTrace) -> (Vec<LinkAgg>, u64) {
    let mut by_link: Vec<LinkAgg> = Vec::new();
    let mut span = 0u64;
    for rec in &t.steps {
        span += rec.t_end.saturating_sub(rec.t_start);
        for s in &rec.links {
            let agg = match by_link.iter_mut().find(|a| a.link == s.link) {
                Some(a) => a,
                None => {
                    by_link.push(LinkAgg {
                        link: s.link,
                        busy_steps: 0,
                        events: 0,
                        busy: 0,
                        queue_hw: 0,
                    });
                    by_link.last_mut().expect("just pushed")
                }
            };
            agg.busy_steps += 1;
            agg.events += u64::from(s.events);
            agg.busy += s.busy;
            agg.queue_hw = agg.queue_hw.max(s.queue_hw);
        }
    }
    by_link.sort_by(|a, b| (b.busy, b.events).cmp(&(a.busy, a.events)).then(a.link.cmp(&b.link)));
    (by_link, span)
}

/// Human-readable analysis of a parsed trace: per-tile utilisation,
/// per-link utilisation, queue-depth percentiles, and the critical-path
/// superstep histogram (per-superstep simulated duration on a log2 scale —
/// the long buckets are the supersteps that set the makespan).
pub fn summarize(file: &TraceFile) -> String {
    let t = &file.trace;
    let recorded = t.steps.len();
    let mut out = String::new();
    out.push_str(&format!(
        "trace: {} tiles, {} segment(s), {} superstep(s) observed ({} recorded, {} dropped by ring bound {})\n",
        t.n_tiles, t.segments, t.total_steps, recorded, t.dropped_steps, t.max_steps
    ));
    if t.dropped_steps > 0 {
        out.push_str(&format!(
            "WARNING: steps_dropped = {} — the ring bound ({}) evicted the earliest supersteps; this analysis covers only the final {} recorded.\n",
            t.dropped_steps, t.max_steps, recorded
        ));
    }
    if recorded == 0 {
        out.push_str("no step records to analyse\n");
        return out;
    }

    // Per-tile utilisation: a tile is "busy" in a superstep iff it appears
    // in that step's samples.
    let n = t.n_tiles as usize;
    let mut busy = vec![0u64; n];
    let mut copies = vec![0u64; n];
    let mut lanes = vec![0u64; n];
    let mut queue_hw = vec![0u32; n];
    for rec in &t.steps {
        for s in &rec.tiles {
            let i = s.tile as usize;
            if i < n {
                busy[i] += 1;
                copies[i] += s.copies;
                lanes[i] += s.lanes;
                queue_hw[i] = queue_hw[i].max(s.queue_hw);
            }
        }
    }
    let mut active: Vec<usize> = (0..n).filter(|&i| busy[i] > 0).collect();
    active.sort_by(|&a, &b| (busy[b], copies[b]).cmp(&(busy[a], copies[a])).then(a.cmp(&b)));
    let mut table = Table::new(&["tile", "busy steps", "util %", "copies", "lanes", "queue hw"]);
    for &i in active.iter().take(SUMMARY_TILE_ROWS) {
        table.row(vec![
            i.to_string(),
            fmt_count(busy[i]),
            format!("{:.1}", 100.0 * busy[i] as f64 / recorded as f64),
            fmt_count(copies[i]),
            fmt_count(lanes[i]),
            queue_hw[i].to_string(),
        ]);
    }
    out.push_str(&table.render());
    if active.len() > SUMMARY_TILE_ROWS {
        out.push_str(&format!(
            "(+{} more active tiles not shown; {} tiles never delivered)\n",
            active.len() - SUMMARY_TILE_ROWS,
            n - active.len()
        ));
    } else if active.len() < n {
        out.push_str(&format!("({} tiles never delivered)\n", n - active.len()));
    }

    // Per-link utilisation over the recorded window: busy cycles against
    // the summed superstep durations.
    let (links, span) = aggregate_links(t);
    if links.is_empty() {
        out.push_str("no inter-board link traffic recorded\n");
    } else {
        let util = |a: &LinkAgg| {
            if span == 0 { 0.0 } else { 100.0 * a.busy as f64 / span as f64 }
        };
        let mut lt =
            Table::new(&["link", "busy steps", "events", "busy cycles", "util %", "queue hw"]);
        for a in links.iter().take(SUMMARY_LINK_ROWS) {
            lt.row(vec![
                LinkSample::name(a.link),
                fmt_count(a.busy_steps),
                fmt_count(a.events),
                fmt_count(a.busy),
                format!("{:.1}", util(a)),
                a.queue_hw.to_string(),
            ]);
        }
        out.push_str(&lt.render());
        if links.len() > SUMMARY_LINK_ROWS {
            out.push_str(&format!(
                "(+{} more active links not shown)\n",
                links.len() - SUMMARY_LINK_ROWS
            ));
        }
        let top: Vec<String> = links
            .iter()
            .take(TOP_CONGESTED_LINKS)
            .map(|a| format!("{} {:.1}%", LinkSample::name(a.link), util(a)))
            .collect();
        out.push_str(&format!("top congested links: {}\n", top.join("  ")));
    }

    // Queue-depth percentiles over per-superstep high-water marks.
    let depths: Vec<f64> = t.steps.iter().map(|r| f64::from(r.queue_hw)).collect();
    out.push_str(&format!(
        "queue depth high-water: p50 {:.0}  p90 {:.0}  p99 {:.0}  max {:.0}\n",
        percentile(&depths, 50.0),
        percentile(&depths, 90.0),
        percentile(&depths, 99.0),
        depths.iter().cloned().fold(0.0f64, f64::max),
    ));

    // Critical-path superstep histogram: log2 buckets of simulated cycles.
    let mut hist = [0u64; super::span::LATENCY_BUCKETS];
    for rec in &t.steps {
        hist[log2_bucket(rec.t_end.saturating_sub(rec.t_start))] += 1;
    }
    let last = hist.iter().rposition(|&c| c > 0).unwrap_or(0);
    out.push_str("superstep duration histogram (cycles, log2 buckets):\n");
    for (i, &count) in hist.iter().enumerate().take(last + 1) {
        let lo = if i == 0 { 0 } else { 1u64 << i };
        out.push_str(&format!("  >= {:>8}: {}\n", lo, fmt_count(count)));
    }
    out
}

/// Schema tag on the machine-readable summary (`trace summarize --json`).
pub const TRACE_SUMMARY_SCHEMA: &str = "poets-impute/trace-summary/v1";

/// Machine-readable counterpart of [`summarize`]: the same aggregates —
/// truncation accounting, tile activity, per-link utilisation, queue
/// percentiles — as a single JSON object for scripting and CI greps.
pub fn summarize_json(file: &TraceFile) -> Json {
    let t = &file.trace;
    let recorded = t.steps.len();
    let mut doc = Json::obj();
    doc.set("schema", TRACE_SUMMARY_SCHEMA)
        .set("n_tiles", t.n_tiles as u64)
        .set("segments", t.segments as u64)
        .set("total_steps", t.total_steps)
        .set("steps_recorded", recorded)
        .set("steps_dropped", t.dropped_steps)
        .set("truncated", t.dropped_steps > 0)
        .set("max_steps", t.max_steps);

    let mut active_tiles = std::collections::BTreeSet::new();
    let mut copies = 0u64;
    let mut lanes = 0u64;
    for rec in &t.steps {
        copies += rec.copies;
        lanes += rec.lanes;
        for s in &rec.tiles {
            active_tiles.insert(s.tile);
        }
    }
    doc.set("active_tiles", active_tiles.len())
        .set("copies", copies)
        .set("lanes", lanes);

    let depths: Vec<f64> = t.steps.iter().map(|r| f64::from(r.queue_hw)).collect();
    let mut q = Json::obj();
    q.set("p50", percentile(&depths, 50.0))
        .set("p90", percentile(&depths, 90.0))
        .set("p99", percentile(&depths, 99.0))
        .set("max", depths.iter().cloned().fold(0.0f64, f64::max));
    doc.set("queue_hw", q);

    let (links, span) = aggregate_links(t);
    let link_events: u64 = links.iter().map(|a| a.events).sum();
    let link_busy: u64 = links.iter().map(|a| a.busy).sum();
    let mut link_arr = Json::Arr(Vec::new());
    for a in &links {
        let mut l = Json::obj();
        l.set("link", a.link as u64)
            .set("name", LinkSample::name(a.link))
            .set("busy_steps", a.busy_steps)
            .set("events", a.events)
            .set("busy_cycles", a.busy)
            .set(
                "utilisation",
                if span == 0 { 0.0 } else { a.busy as f64 / span as f64 },
            )
            .set("queue_hw", a.queue_hw as u64);
        link_arr.push(l);
    }
    doc.set("recorded_span_cycles", span)
        .set("link_events", link_events)
        .set("link_busy", link_busy)
        .set("active_links", links.len())
        .set(
            "max_link_utilisation",
            if span == 0 || links.is_empty() {
                0.0
            } else {
                links.iter().map(|a| a.busy).max().unwrap_or(0) as f64 / span as f64
            },
        )
        .set("links", link_arr);
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> RunTrace {
        let cfg = TraceConfig { max_steps: 8, col_stride: Some(4) };
        let mut t = RunTrace::new(cfg, 3);
        for step in 0..3u64 {
            t.push(StepRecord {
                segment: 0,
                step,
                t_start: step * 100,
                t_end: (step + 1) * 100,
                busy_tiles: 2,
                copies: 10 + step,
                lanes: 80 + step,
                queue_hw: 4,
                col_min: 1,
                col_max: 2,
                link_events: 3,
                link_busy: 33 + step,
                tiles: vec![
                    TileSample { tile: 0, queue_hw: 4, copies: 6, lanes: 48, col_min: 1, col_max: 1 },
                    TileSample { tile: 2, queue_hw: 3, copies: 4 + step, lanes: 32 + step, col_min: 2, col_max: 2 },
                ],
                links: vec![
                    LinkSample { link: 0, events: 2, busy: 22 + step, queue_hw: 1 },
                    LinkSample { link: 5, events: 1, busy: 11, queue_hw: 0 },
                ],
            });
        }
        t
    }

    #[test]
    fn ring_bound_drops_oldest_and_counts() {
        let mut t = RunTrace::new(TraceConfig { max_steps: 2, col_stride: None }, 1);
        for step in 0..5u64 {
            t.push(StepRecord {
                segment: 0,
                step,
                t_start: step,
                t_end: step + 1,
                busy_tiles: 0,
                copies: 0,
                lanes: 0,
                queue_hw: 0,
                col_min: NO_COL,
                col_max: NO_COL,
                link_events: 0,
                link_busy: 0,
                tiles: Vec::new(),
                links: Vec::new(),
            });
        }
        assert_eq!(t.steps.len(), 2);
        assert_eq!(t.dropped_steps, 3);
        assert_eq!(t.total_steps, 5);
        assert_eq!(t.steps[0].step, 3);
    }

    #[test]
    fn absorb_renumbers_segments() {
        let mut a = sample_trace();
        let b = sample_trace();
        a.absorb(b);
        assert_eq!(a.segments, 2);
        assert_eq!(a.total_steps, 6);
        assert!(a.steps.iter().take(3).all(|r| r.segment == 0));
        assert!(a.steps.iter().skip(3).all(|r| r.segment == 1));
    }

    #[test]
    fn jsonl_round_trips_byte_identically() {
        let t = sample_trace();
        let mut rc = Json::obj();
        rc.set("source", "unit-test");
        let text = t.to_jsonl(rc);
        let parsed = TraceFile::parse(&text).expect("parse");
        assert_eq!(parsed.trace, t);
        assert_eq!(parsed.render(), text, "parse -> render must round-trip");
    }

    #[test]
    fn parser_rejects_malformed_lines_with_line_numbers() {
        let t = sample_trace();
        let text = t.to_jsonl(Json::obj());
        let mut lines: Vec<&str> = text.lines().collect();

        let err = TraceFile::parse("").unwrap_err();
        assert!(err.contains("empty"), "{err}");

        let bad_json = text.replace("\"kind\":\"step\"", "\"kind\":");
        let err = TraceFile::parse(&bad_json).unwrap_err();
        assert!(err.starts_with("line 2:"), "{err}");

        lines[1] = "{\"kind\":\"mystery\"}";
        let err = TraceFile::parse(&lines.join("\n")).unwrap_err();
        assert!(err.contains("line 2") && err.contains("mystery"), "{err}");

        let truncated: String = text.lines().take(2).map(|l| format!("{l}\n")).collect();
        let err = TraceFile::parse(&truncated).unwrap_err();
        assert!(err.contains("declares"), "{err}");

        let wrong_schema = text.replace(TRACE_SCHEMA, "poets-impute/trace/v0");
        let err = TraceFile::parse(&wrong_schema).unwrap_err();
        assert!(err.contains("schema"), "{err}");
    }

    #[test]
    fn summarize_reports_tiles_and_percentiles() {
        let t = sample_trace();
        let file = TraceFile::parse(&t.to_jsonl(Json::obj())).expect("parse");
        let s = summarize(&file);
        assert!(s.contains("3 tiles"), "{s}");
        assert!(s.contains("queue depth high-water"), "{s}");
        assert!(s.contains("superstep duration histogram"), "{s}");
        // Tile 1 never delivers.
        assert!(s.contains("1 tiles never delivered"), "{s}");
        // Link 0E carries more busy cycles than 1W, so it leads the table
        // and the congestion line.
        assert!(s.contains("top congested links: 0E"), "{s}");
        assert!(s.contains("1W"), "{s}");
        // Nothing dropped → no truncation warning.
        assert!(!s.contains("WARNING"), "{s}");
    }

    #[test]
    fn link_names_follow_dir_order() {
        assert_eq!(LinkSample::name(0), "0E");
        assert_eq!(LinkSample::name(1), "0W");
        assert_eq!(LinkSample::name(2), "0N");
        assert_eq!(LinkSample::name(3), "0S");
        assert_eq!(LinkSample::name(13), "3N");
    }

    #[test]
    fn parser_requires_link_fields() {
        let t = sample_trace();
        let text = t.to_jsonl(Json::obj());

        let no_links = text.replace(",\"links\":[[0,2,22,1],[5,1,11,0]]", "");
        let err = TraceFile::parse(&no_links).unwrap_err();
        assert!(err.contains("links"), "{err}");

        let short_link = text.replace("[5,1,11,0]", "[5,1,11]");
        let err = TraceFile::parse(&short_link).unwrap_err();
        assert!(err.contains("4"), "{err}");

        let no_events = text.replace("\"link_events\":3,", "");
        let err = TraceFile::parse(&no_events).unwrap_err();
        assert!(err.contains("link_events"), "{err}");
    }

    #[test]
    fn truncated_trace_is_reported_honestly() {
        let mut t = sample_trace();
        t.max_steps = 2;
        t.enforce_bound();
        assert_eq!(t.dropped_steps, 1);
        let text = t.to_jsonl(Json::obj());
        let header = text.lines().next().expect("header");
        assert!(header.contains("\"dropped_steps\":1"), "{header}");
        assert!(header.contains("\"truncated\":true"), "{header}");
        let file = TraceFile::parse(&text).expect("parse");
        let s = summarize(&file);
        assert!(s.contains("WARNING: steps_dropped = 1"), "{s}");
        let j = summarize_json(&file);
        assert_eq!(j.get("steps_dropped").and_then(Json::as_i64), Some(1));
        assert_eq!(j.get("truncated"), Some(&Json::Bool(true)));
    }

    #[test]
    fn summarize_json_aggregates_links() {
        let t = sample_trace();
        let file = TraceFile::parse(&t.to_jsonl(Json::obj())).expect("parse");
        let j = summarize_json(&file);
        assert_eq!(
            j.get("schema").and_then(Json::as_str),
            Some(TRACE_SUMMARY_SCHEMA)
        );
        assert_eq!(j.get("steps_recorded").and_then(Json::as_i64), Some(3));
        assert_eq!(j.get("active_links").and_then(Json::as_i64), Some(2));
        // 3 steps × (2 + 1) events per step.
        assert_eq!(j.get("link_events").and_then(Json::as_i64), Some(9));
        // Busy: (22+23+24) + 3×11 = 102; span = 3 × 100 cycles.
        assert_eq!(j.get("link_busy").and_then(Json::as_i64), Some(102));
        assert_eq!(j.get("recorded_span_cycles").and_then(Json::as_i64), Some(300));
        let links = j.get("links").and_then(Json::as_arr).expect("links");
        assert_eq!(links.len(), 2);
        assert_eq!(links[0].get("name").and_then(Json::as_str), Some("0E"));
        let util = j.get("max_link_utilisation").and_then(Json::as_f64).expect("util");
        assert!((util - 69.0 / 300.0).abs() < 1e-9, "{util}");
        // Document must be valid renderable JSON.
        assert!(Json::parse(&j.pretty()).is_ok());
    }
}
