//! Chrome `trace_event` export: turns a parsed `poets-impute/trace/v1`
//! file into the Trace Event Format object (`{"traceEvents":[...]}`)
//! understood by Perfetto and `chrome://tracing`.
//!
//! Mapping:
//!
//! * one `"X"` (complete) event per (superstep, tile) sample —
//!   `pid` = segment, `tid` = tile, `ts`/`dur` = the superstep's
//!   simulated-cycle span (the viewer displays them as microseconds);
//! * per-superstep `"C"` (counter) events for busy tiles, delivered
//!   copies/lanes, and the queue-depth high-water, plus a second `"noc"`
//!   counter track with inter-board link crossings, link-busy cycles and
//!   the worst per-link queue high-water;
//! * `"M"` (metadata) events naming each segment's process row.
//!
//! Segments each start at simulated time 0, so successive segments are
//! laid out end-to-end on the export timeline (a cumulative base offset
//! per segment) instead of overlapping.

use crate::util::json::Json;

use super::trace::{TraceFile, NO_COL};

fn event(ph: &str, name: &str, pid: u32, tid: u32) -> Json {
    let mut e = Json::obj();
    e.set("ph", ph)
        .set("name", name)
        .set("pid", pid as u64)
        .set("tid", tid as u64)
        .set("cat", "desim");
    e
}

/// Build the Chrome trace object. Deterministic: event order follows the
/// trace's (segment, step, tile) order.
pub fn to_chrome(file: &TraceFile) -> Json {
    let t = &file.trace;
    let mut events = Json::Arr(Vec::new());

    for seg in 0..t.segments {
        let mut meta = event("M", "process_name", seg, 0);
        let mut args = Json::obj();
        args.set("name", format!("desim segment {seg}"));
        meta.set("args", args);
        events.push(meta);
    }

    // Per-segment cumulative time base so segments don't overlap.
    let mut base = 0u64;
    let mut cur_seg = 0u32;
    let mut cur_end = 0u64;
    for rec in &t.steps {
        if rec.segment != cur_seg {
            base += cur_end;
            cur_end = 0;
            cur_seg = rec.segment;
        }
        cur_end = cur_end.max(rec.t_end);
        let ts = base + rec.t_start;
        let dur = rec.t_end.saturating_sub(rec.t_start);

        for s in &rec.tiles {
            let mut e = event("X", "deliver", rec.segment, s.tile);
            e.set("ts", ts).set("dur", dur);
            let mut args = Json::obj();
            args.set("step", rec.step)
                .set("queue_hw", s.queue_hw as u64)
                .set("copies", s.copies)
                .set("lanes", s.lanes);
            if s.col_min != NO_COL {
                args.set("col_min", s.col_min as u64).set("col_max", s.col_max as u64);
            }
            e.set("args", args);
            events.push(e);
        }

        let mut c = event("C", "occupancy", rec.segment, 0);
        c.set("ts", ts);
        let mut args = Json::obj();
        args.set("busy_tiles", rec.busy_tiles as u64)
            .set("queue_hw", rec.queue_hw as u64)
            .set("copies", rec.copies)
            .set("lanes", rec.lanes);
        c.set("args", args);
        events.push(c);

        let mut noc = event("C", "noc", rec.segment, 0);
        noc.set("ts", ts);
        let mut args = Json::obj();
        args.set("link_events", rec.link_events)
            .set("link_busy", rec.link_busy)
            .set(
                "link_queue_hw",
                rec.links.iter().map(|l| u64::from(l.queue_hw)).max().unwrap_or(0),
            );
        noc.set("args", args);
        events.push(noc);
    }

    let mut doc = Json::obj();
    doc.set("traceEvents", events).set("displayTimeUnit", "ms");
    doc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{LinkSample, RunTrace, StepRecord, TileSample, TraceConfig};

    fn two_segment_trace() -> TraceFile {
        let cfg = TraceConfig { max_steps: 16, col_stride: Some(4) };
        let mut a = RunTrace::new(cfg, 2);
        for step in 0..2u64 {
            a.push(StepRecord {
                segment: 0,
                step,
                t_start: step * 50,
                t_end: step * 50 + 40,
                busy_tiles: 1,
                copies: 3,
                lanes: 24,
                queue_hw: 2,
                col_min: 0,
                col_max: 1,
                link_events: 2,
                link_busy: 22,
                tiles: vec![TileSample {
                    tile: (step % 2) as u32,
                    queue_hw: 2,
                    copies: 3,
                    lanes: 24,
                    col_min: 0,
                    col_max: 1,
                }],
                links: vec![LinkSample { link: 0, events: 2, busy: 22, queue_hw: 1 }],
            });
        }
        let b = a.clone();
        a.absorb(b);
        let text = a.to_jsonl(Json::obj());
        TraceFile::parse(&text).expect("parse")
    }

    #[test]
    fn export_is_structurally_valid_trace_event_json() {
        let doc = to_chrome(&two_segment_trace());
        let events = match doc.get("traceEvents") {
            Some(Json::Arr(xs)) => xs,
            other => panic!("traceEvents missing or not an array: {other:?}"),
        };
        assert!(!events.is_empty());
        let mut complete = 0;
        let mut counters = 0;
        for e in events {
            let ph = e.get("ph").and_then(Json::as_str).expect("ph");
            assert!(e.get("pid").and_then(Json::as_i64).is_some());
            assert!(e.get("tid").and_then(Json::as_i64).is_some());
            match ph {
                "X" => {
                    complete += 1;
                    assert!(e.get("name").and_then(Json::as_str).is_some());
                    assert!(e.get("ts").and_then(Json::as_i64).unwrap() >= 0);
                    assert!(e.get("dur").and_then(Json::as_i64).unwrap() >= 0);
                }
                "C" => {
                    counters += 1;
                    assert!(e.get("args").is_some());
                }
                "M" => {}
                other => panic!("unexpected phase {other:?}"),
            }
        }
        assert_eq!(complete, 4, "one X event per (step, tile) sample");
        assert_eq!(counters, 8, "occupancy + noc counter events per step");
        let noc: Vec<_> = events
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("noc"))
            .collect();
        assert_eq!(noc.len(), 4, "one noc counter track sample per step");
        assert!(noc
            .iter()
            .all(|e| e.get("args").unwrap().get("link_events").and_then(Json::as_i64) == Some(2)));
        // Round-trip through the parser: the export itself must be valid JSON.
        assert!(Json::parse(&doc.render()).is_ok());
    }

    #[test]
    fn segments_are_laid_out_end_to_end() {
        let doc = to_chrome(&two_segment_trace());
        let events = match doc.get("traceEvents") {
            Some(Json::Arr(xs)) => xs.clone(),
            _ => unreachable!(),
        };
        let seg_ts = |seg: i64| -> Vec<i64> {
            events
                .iter()
                .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
                .filter(|e| e.get("pid").and_then(Json::as_i64) == Some(seg))
                .map(|e| e.get("ts").and_then(Json::as_i64).unwrap())
                .collect()
        };
        let s0 = seg_ts(0);
        let s1 = seg_ts(1);
        assert!(!s0.is_empty() && !s1.is_empty());
        let s0_end = s0.iter().max().unwrap() + 40;
        assert!(
            s1.iter().all(|&ts| ts >= s0_end),
            "segment 1 must start after segment 0 ends: {s0:?} vs {s1:?}"
        );
    }
}
