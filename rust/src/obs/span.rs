//! Log-scale latency buckets shared by the serve plane's request-span
//! histograms (`serve-stats/v1`) and the trace summariser.
//!
//! Buckets are powers of two: bucket `i` counts values in
//! `[2^i, 2^(i+1))` (bucket 0 additionally holds 0), saturating at the
//! last bucket. With microsecond inputs the layout spans 1 µs … ≥ 32.8 ms
//! per bucket boundary up to the ≥ 32768 µs catch-all at index 15 — wide
//! enough for queue waits and service times on this workload while keeping
//! `ServiceStats` a flat `Copy` struct (fixed-size arrays, no allocation).

/// Number of power-of-two buckets in every latency histogram.
pub const LATENCY_BUCKETS: usize = 16;

/// Generic log2 bucket index of `v`, saturating at
/// [`LATENCY_BUCKETS`]` - 1`. `0` and `1` both land in bucket 0.
pub fn log2_bucket(v: u64) -> usize {
    if v <= 1 {
        return 0;
    }
    let b = (63 - v.leading_zeros()) as usize;
    b.min(LATENCY_BUCKETS - 1)
}

/// Bucket index of a latency in microseconds.
pub fn latency_bucket(us: u64) -> usize {
    log2_bucket(us)
}

/// Half-open `[lo, hi)` bounds of bucket `i` (the last bucket's upper
/// bound is `u64::MAX`).
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    assert!(i < LATENCY_BUCKETS, "bucket {i} out of range");
    let lo = if i == 0 { 0 } else { 1u64 << i };
    let hi = if i + 1 == LATENCY_BUCKETS { u64::MAX } else { 1u64 << (i + 1) };
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_partition_the_line() {
        assert_eq!(latency_bucket(0), 0);
        assert_eq!(latency_bucket(1), 0);
        assert_eq!(latency_bucket(2), 1);
        assert_eq!(latency_bucket(3), 1);
        assert_eq!(latency_bucket(4), 2);
        assert_eq!(latency_bucket(u64::MAX), LATENCY_BUCKETS - 1);
        for us in [0u64, 1, 2, 5, 100, 1 << 14, (1 << 15) - 1, 1 << 15, 1 << 40] {
            let i = latency_bucket(us);
            let (lo, hi) = bucket_bounds(i);
            assert!(us >= lo && us < hi || (i == LATENCY_BUCKETS - 1 && us >= lo),
                "{us} not in [{lo}, {hi}) of bucket {i}");
        }
    }

    #[test]
    fn bounds_are_contiguous() {
        for i in 1..LATENCY_BUCKETS {
            assert_eq!(bucket_bounds(i - 1).1, bucket_bounds(i).0);
        }
    }
}
