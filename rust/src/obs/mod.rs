//! Opt-in observability plane: DES traces, serve request spans, exports.
//!
//! Everything in this module is **off by default** and costs one branch on
//! an `Option` when disabled — no allocation, no atomics on the simulator
//! hot path, so `desim_hotpath` numbers are unchanged with tracing off.
//!
//! Three pieces:
//!
//! * [`trace`] — per-superstep, per-tile DES telemetry captured inside
//!   `poets::desim` (enabled via `SimConfig::trace`), merged in the
//!   simulator's deterministic serial shard reduce so, at a fixed
//!   wave/batch width, the emitted trace is bit-identical for any
//!   `threads` value; serialised as `poets-impute/trace/v1` JSONL.
//! * [`chrome`] — converts a parsed trace into Chrome `trace_event` JSON
//!   (the object format), loadable in Perfetto or `chrome://tracing`.
//! * [`span`] — the log-scale latency bucket layout shared by the serve
//!   plane's per-request span timelines and the `serve-stats/v1`
//!   histograms.
//!
//! The CLI front end is `cli trace summarize|export` (see `cli::commands`);
//! traces are produced by `impute --trace PATH` and
//! `cargo bench --bench desim_hotpath -- --trace`.

pub mod chrome;
pub mod span;
pub mod trace;

pub use span::{bucket_bounds, latency_bucket, LATENCY_BUCKETS};
pub use trace::{
    LinkSample, RunTrace, StepRecord, TileSample, TraceConfig, TraceFile, NO_COL, TRACE_SCHEMA,
    TRACE_SUMMARY_SCHEMA,
};
