//! Windowed chunking: run any engine on panels larger than one graph build.
//!
//! A chromosome-scale panel does not fit one event-driven application graph
//! (the mapping layer rejects graphs beyond the cluster's thread capacity),
//! and even on the x86 planes one monolithic run serialises poorly.  The
//! standard solution — GEDI-style window slicing — carves the marker axis
//! into overlapping windows, imputes each window independently, and stitches
//! per-window dosages back together.
//!
//! * [`WindowPlan`] — the slicing: fixed-length windows at a fixed stride,
//!   every marker covered, the last window shifted left (never shortened) so
//!   ragged tails still get a full-length window.  Each window owns a
//!   disjoint **core** interval; cores partition the marker axis and the
//!   boundary between two cores sits at the midpoint of their windows'
//!   overlap, so every core marker is buffered from its window edge by half
//!   the overlap — where the Li & Stephens chain has forgotten the window
//!   boundary condition.
//! * [`WindowPlan::slice_workload`] — one [`Workload`] per window: panel
//!   columns via
//!   [`ReferencePanel::select_markers`](crate::model::panel::ReferencePanel::select_markers)
//!   (contiguous ranges keep genetic distances bit-exact) and target
//!   observations sliced to match.
//! * [`stitch`] — merge per-window dosage matrices by copying each window's
//!   core columns into the full-width result.
//! * [`run_windowed`] — the whole pipeline over [`ImputeSession`]: slice,
//!   run every window on the configured engine, stitch, re-score accuracy
//!   against the full workload's truth, and merge timings/metrics into one
//!   [`ImputeReport`] (its `windows` field records the plan size).
//!
//! Windowing composes with any [`EngineSpec`](crate::session::EngineSpec):
//! the per-window runs are ordinary sessions, so the event planes keep their
//! determinism guarantees (a windowed run is bit-identical for any host
//! thread count), and a single-window plan reproduces the unwindowed run
//! bit-for-bit.
//!
//! One caveat: the linear-interpolation plane imputes only between a
//! window's first and last *annotated* markers (that is its model, on
//! windows as on whole chromosomes), so windowing an interp workload is
//! only full-coverage when window boundaries land on the chip grid.  The
//! dense planes (baseline/rank1/event/xla) have no such constraint.

use crate::model::accuracy;
use crate::model::panel::TargetHaplotype;
use crate::session::{ImputeReport, ImputeSession, Workload};

/// One marker window: `[start, end)` is what an engine sees, `[core_start,
/// core_end)` is the sub-interval whose dosages the stitcher keeps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MarkerWindow {
    pub start: usize,
    pub end: usize,
    pub core_start: usize,
    pub core_end: usize,
}

impl MarkerWindow {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }
}

/// A full slicing of `0..n_mark` into overlapping windows with disjoint
/// cores.  Construction is total over its domain: any `window_len >= 2` and
/// `overlap < window_len` yields a valid plan for any `n_mark >= 2`.
#[derive(Clone, Debug)]
pub struct WindowPlan {
    n_mark: usize,
    windows: Vec<MarkerWindow>,
}

impl WindowPlan {
    /// Plan windows of `window_len` markers overlapping by `overlap`.
    ///
    /// `window_len` clamps to the panel width (a window cannot exceed the
    /// chromosome), so `window_len >= n_mark` yields the single-window plan.
    /// Errors, not panics: window geometry arrives from CLI flags and
    /// request fields.
    pub fn new(n_mark: usize, window_len: usize, overlap: usize) -> Result<WindowPlan, String> {
        if n_mark < 2 {
            return Err(format!("cannot window a {n_mark}-marker panel (need >= 2)"));
        }
        if window_len < 2 {
            return Err(format!("window length {window_len} too small (need >= 2)"));
        }
        let w = window_len.min(n_mark);
        if overlap >= w {
            return Err(format!(
                "overlap {overlap} must be smaller than the effective window length {w}"
            ));
        }
        let stride = w - overlap;
        let mut spans: Vec<(usize, usize)> = Vec::new();
        let mut start = 0usize;
        loop {
            let end = start + w;
            spans.push((start, end));
            if end >= n_mark {
                break;
            }
            // Keep full-length windows: when the next regular stride would
            // overshoot, shift it left to end exactly at the chromosome end
            // (the overlap with the previous window grows, never shrinks).
            start = if start + stride + w > n_mark {
                n_mark - w
            } else {
                start + stride
            };
        }
        // Core boundaries: midpoints of consecutive windows' overlaps.
        let mut windows = Vec::with_capacity(spans.len());
        for (i, &(start, end)) in spans.iter().enumerate() {
            let core_start = if i == 0 {
                0
            } else {
                (start + spans[i - 1].1) / 2
            };
            let core_end = if i + 1 == spans.len() {
                n_mark
            } else {
                (spans[i + 1].0 + end) / 2
            };
            windows.push(MarkerWindow {
                start,
                end,
                core_start,
                core_end,
            });
        }
        Ok(WindowPlan { n_mark, windows })
    }

    pub fn n_mark(&self) -> usize {
        self.n_mark
    }

    pub fn windows(&self) -> &[MarkerWindow] {
        &self.windows
    }

    pub fn len(&self) -> usize {
        self.windows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// Assemble the sub-workload one window sees: panel columns `[start,
    /// end)` and every target's observations sliced to match.  Contiguous
    /// `select_markers` ranges pass genetic distances through bit-exactly,
    /// so a single-window plan reproduces the original workload.  Withheld
    /// truth is *not* propagated — per-window accuracy over a fragment is
    /// meaningless; [`run_windowed`] re-scores on the stitched result.
    pub fn slice_workload(&self, full: &Workload, window: &MarkerWindow) -> Workload {
        let marks: Vec<usize> = (window.start..window.end).collect();
        let panel = full.panel().select_markers(&marks);
        let targets: Vec<TargetHaplotype> = full
            .targets()
            .iter()
            .map(|t| TargetHaplotype::new(t.obs[window.start..window.end].to_vec()))
            .collect();
        Workload::from_parts(panel, targets)
    }
}

/// Merge per-window dosage matrices into one full-width matrix: each
/// window contributes exactly its core columns.  `per_window[i]` must be
/// the dosages of window `i` (`[target][marker-within-window]`).
pub fn stitch(plan: &WindowPlan, per_window: &[Vec<Vec<f32>>]) -> Result<Vec<Vec<f32>>, String> {
    if per_window.len() != plan.len() {
        return Err(format!(
            "stitch: {} dosage sets for a {}-window plan",
            per_window.len(),
            plan.len()
        ));
    }
    let n_targets = per_window.first().map_or(0, Vec::len);
    let mut full = vec![vec![0.0f32; plan.n_mark()]; n_targets];
    for (i, (win, dosages)) in plan.windows().iter().zip(per_window).enumerate() {
        if dosages.len() != n_targets {
            return Err(format!(
                "stitch: window {i} has {} targets, window 0 has {n_targets}",
                dosages.len()
            ));
        }
        for (t, row) in dosages.iter().enumerate() {
            if row.len() != win.len() {
                return Err(format!(
                    "stitch: window {i} target {t} has {} markers, window spans {}",
                    row.len(),
                    win.len()
                ));
            }
            full[t][win.core_start..win.core_end]
                .copy_from_slice(&row[win.core_start - win.start..win.core_end - win.start]);
        }
    }
    Ok(full)
}

/// Run a workload window-by-window and stitch one report.
///
/// `configure` applies the engine selection and knobs to each per-window
/// session (it receives a fresh `ImputeSession::new(window_workload)` and
/// must return the configured builder) — the same closure shape the CLI
/// builds from its flags.  The merged report carries the stitched dosages,
/// summed host/simulated timings, accumulated DES counters, accuracy
/// re-scored against the full workload's truth, and `windows = plan.len()`.
pub fn run_windowed<F>(
    full: &Workload,
    plan: &WindowPlan,
    configure: F,
) -> Result<ImputeReport, String>
where
    F: Fn(ImputeSession) -> ImputeSession,
{
    if plan.n_mark() != full.panel().n_mark() {
        return Err(format!(
            "window plan covers {} markers, workload has {}",
            plan.n_mark(),
            full.panel().n_mark()
        ));
    }
    if full.n_targets() == 0 {
        return Err("workload has no targets".into());
    }
    let mut reports = Vec::with_capacity(plan.len());
    for (i, win) in plan.windows().iter().enumerate() {
        let report = configure(ImputeSession::new(plan.slice_workload(full, win)))
            .run()
            .map_err(|e| format!("window {i} ([{}, {})): {e}", win.start, win.end))?;
        reports.push(report);
    }
    // Drain the per-window dosages rather than cloning them: on the
    // chromosome-scale runs windowing exists for, the dosage matrices are
    // the dominant allocation.
    let per_window: Vec<Vec<Vec<f32>>> = reports
        .iter_mut()
        .map(|r| std::mem::take(&mut r.dosages))
        .collect();
    let dosages = stitch(plan, &per_window)?;
    drop(per_window);

    let accuracy = full
        .truth()
        .map(|truth| accuracy::score_set(&dosages, truth, full.targets()));

    let mut merged = reports.remove(0);
    for r in &reports {
        merged.host_seconds += r.host_seconds;
        merged.n_batches += r.n_batches;
        if let Some(s) = r.sim_seconds {
            *merged.sim_seconds.get_or_insert(0.0) += s;
        }
        if let Some(m) = &r.metrics {
            match &mut merged.metrics {
                None => merged.metrics = Some(m.clone()),
                Some(acc) => acc.absorb(m),
            }
        }
    }
    merged.n_mark = full.panel().n_mark();
    merged.dosages = dosages;
    merged.accuracy = accuracy;
    merged.provenance = full.provenance().copied();
    merged.windows = Some(plan.len());
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{EngineSpec, max_abs_dosage_diff};
    use crate::workload::panelgen::PanelConfig;

    fn plan(n_mark: usize, w: usize, v: usize) -> WindowPlan {
        WindowPlan::new(n_mark, w, v).unwrap()
    }

    fn workload(n_mark: usize, n_targets: usize) -> Workload {
        Workload::synthetic(
            &PanelConfig {
                n_hap: 8,
                n_mark,
                maf: 0.2,
                annot_ratio: 0.25,
                seed: 77,
                ..PanelConfig::default()
            },
            n_targets,
        )
    }

    #[test]
    fn plan_covers_and_partitions() {
        let p = plan(40, 20, 10);
        assert_eq!(p.len(), 3);
        assert_eq!(
            p.windows()[0],
            MarkerWindow { start: 0, end: 20, core_start: 0, core_end: 15 }
        );
        assert_eq!(
            p.windows()[1],
            MarkerWindow { start: 10, end: 30, core_start: 15, core_end: 25 }
        );
        assert_eq!(
            p.windows()[2],
            MarkerWindow { start: 20, end: 40, core_start: 25, core_end: 40 }
        );
    }

    #[test]
    fn ragged_tail_shifts_the_last_window() {
        // 45 markers, windows of 20, stride 10: the last window would start
        // at 30 and overshoot, so it shifts to [25, 45) — still 20 long.
        let p = plan(45, 20, 10);
        let last = *p.windows().last().unwrap();
        assert_eq!((last.start, last.end), (25, 45));
        assert!(p.windows().iter().all(|w| w.len() == 20));
        assert_eq!(last.core_end, 45);
    }

    #[test]
    fn single_window_when_panel_fits() {
        for w in [40, 64, 1000] {
            let p = plan(40, w, 8);
            assert_eq!(p.len(), 1);
            assert_eq!(
                p.windows()[0],
                MarkerWindow { start: 0, end: 40, core_start: 0, core_end: 40 }
            );
        }
    }

    #[test]
    fn zero_overlap_abuts() {
        let p = plan(40, 10, 0);
        assert_eq!(p.len(), 4);
        for (i, w) in p.windows().iter().enumerate() {
            assert_eq!((w.start, w.end), (10 * i, 10 * i + 10));
            assert_eq!((w.core_start, w.core_end), (10 * i, 10 * i + 10));
        }
    }

    #[test]
    fn bad_geometry_is_an_error() {
        assert!(WindowPlan::new(1, 4, 0).is_err());
        assert!(WindowPlan::new(40, 1, 0).is_err());
        assert!(WindowPlan::new(40, 8, 8).is_err());
        assert!(WindowPlan::new(40, 8, 12).is_err());
        // Overlap checked against the *effective* (clamped) length.
        assert!(WindowPlan::new(10, 100, 50).is_err());
        assert!(WindowPlan::new(10, 100, 5).is_ok());
    }

    #[test]
    fn sliced_workload_matches_columns() {
        let wl = workload(30, 2);
        let p = plan(30, 12, 4);
        let win = p.windows()[1];
        let sub = p.slice_workload(&wl, &win);
        assert_eq!(sub.panel().n_mark(), win.len());
        assert_eq!(sub.n_targets(), 2);
        assert!(sub.truth().is_none());
        for m in 0..win.len() {
            assert_eq!(sub.panel().column(m), wl.panel().column(win.start + m));
            // Interior distances pass through bit-exactly.
            if m > 0 {
                assert_eq!(
                    sub.panel().gen_dist(m).to_bits(),
                    wl.panel().gen_dist(win.start + m).to_bits()
                );
            }
            assert_eq!(sub.targets()[0].obs[m], wl.targets()[0].obs[win.start + m]);
        }
        assert_eq!(sub.panel().gen_dist(0), 0.0);
    }

    #[test]
    fn stitch_takes_each_core_from_its_window() {
        let p = plan(40, 20, 10);
        // Fill each window's dosages with its own index; the stitched row
        // must read the owning window's index at every marker.
        let per: Vec<Vec<Vec<f32>>> = (0..p.len())
            .map(|i| vec![vec![i as f32; p.windows()[i].len()]; 2])
            .collect();
        let full = stitch(&p, &per).unwrap();
        assert_eq!(full.len(), 2);
        for (i, w) in p.windows().iter().enumerate() {
            for m in w.core_start..w.core_end {
                assert_eq!(full[0][m], i as f32, "marker {m}");
            }
        }
        // Shape mismatches are errors.
        assert!(stitch(&p, &per[..2]).is_err());
        let mut ragged = per.clone();
        ragged[1][0].pop();
        assert!(stitch(&p, &ragged).is_err());
    }

    #[test]
    fn single_window_run_is_bit_identical_to_plain_session() {
        let wl = workload(21, 2);
        let p = plan(21, 64, 4);
        let windowed = run_windowed(&wl, &p, |s| {
            s.engine(EngineSpec::Event).boards(1).states_per_thread(8)
        })
        .unwrap();
        let plain = ImputeSession::new(wl.clone())
            .engine(EngineSpec::Event)
            .boards(1)
            .states_per_thread(8)
            .run()
            .unwrap();
        assert_eq!(windowed.dosages, plain.dosages);
        assert_eq!(windowed.windows, Some(1));
        assert!(windowed.accuracy.is_some(), "truth re-scored on the stitch");
    }

    #[test]
    fn windowed_engines_agree_and_track_the_full_run() {
        let wl = workload(40, 2);
        // Starts (0, 7, 14) avoid the 1-in-4 annotation grid: a window
        // applies no emission at its first marker, so starting on an anchor
        // would discard that anchor's evidence.
        let p = plan(40, 26, 19);
        let base = run_windowed(&wl, &p, |s| s.engine(EngineSpec::Baseline)).unwrap();
        let event = run_windowed(&wl, &p, |s| {
            s.engine(EngineSpec::Event).boards(1).states_per_thread(8)
        })
        .unwrap();
        // Engine equivalence survives windowing (same tolerance as unwindowed).
        assert!(max_abs_dosage_diff(&base.dosages, &event.dosages) <= 1e-3);
        // Cores are buffered by overlap/2 = 8 markers, so the stitched run
        // tracks the full run closely (window boundary conditions decay).
        let full = ImputeSession::new(wl.clone())
            .engine(EngineSpec::Baseline)
            .run()
            .unwrap();
        let drift = max_abs_dosage_diff(&base.dosages, &full.dosages);
        assert!(drift < 0.2, "windowed drifted {drift} from the full run");
        // Accounting merges across windows.
        assert_eq!(event.windows, Some(p.len()));
        assert!(event.sim_seconds.unwrap() > 0.0);
        assert!(event.metrics.unwrap().sends > 0);
        assert_eq!(base.n_mark, 40);
        assert_eq!(base.dosages[0].len(), 40);
    }

    #[test]
    fn plan_mismatch_and_empty_workload_are_errors() {
        let wl = workload(30, 1);
        let p = plan(40, 20, 10);
        assert!(run_windowed(&wl, &p, |s| s).is_err());
        let empty = Workload::from_parts(wl.panel().clone(), Vec::new());
        let p30 = plan(30, 20, 10);
        assert!(run_windowed(&empty, &p30, |s| s).is_err());
    }
}
