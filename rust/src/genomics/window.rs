//! Windowed chunking: run any engine on panels larger than one graph build.
//!
//! A chromosome-scale panel does not fit one event-driven application graph
//! (the mapping layer rejects graphs beyond the cluster's thread capacity),
//! and even on the x86 planes one monolithic run serialises poorly.  The
//! standard solution — GEDI-style window slicing — carves the marker axis
//! into overlapping windows, imputes each window independently, and stitches
//! per-window dosages back together.
//!
//! * [`WindowPlan`] — the slicing: fixed-length windows at a fixed stride,
//!   every marker covered, the last window shifted left (never shortened) so
//!   ragged tails still get a full-length window.  Each window owns a
//!   disjoint **core** interval; cores partition the marker axis and the
//!   boundary between two cores sits at the midpoint of their windows'
//!   overlap, so every core marker is buffered from its window edge by half
//!   the overlap — where the Li & Stephens chain has forgotten the window
//!   boundary condition.
//! * [`WindowPlan::slice_workload`] — one [`Workload`] per window: panel
//!   columns via
//!   [`ReferencePanel::select_markers`](crate::model::panel::ReferencePanel::select_markers)
//!   (contiguous ranges keep genetic distances bit-exact) and target
//!   observations sliced to match.
//! * [`stitch`] — merge per-window dosage matrices by copying each window's
//!   core columns into the full-width result.
//! * [`run_windowed`] — the whole pipeline over [`ImputeSession`]: slice,
//!   run every window on the configured engine, stitch, re-score accuracy
//!   against the full workload's truth, and merge timings/metrics into one
//!   [`ImputeReport`] (its `windows` field records the plan size).
//!
//! Windowing composes with any [`EngineSpec`](crate::session::EngineSpec):
//! the per-window runs are ordinary sessions, so the event planes keep their
//! determinism guarantees (a windowed run is bit-identical for any host
//! thread count), and a single-window plan reproduces the unwindowed run
//! bit-for-bit.
//!
//! Windows are embarrassingly parallel: [`run_windowed_threads`] fans the
//! per-window sessions out over std threads (`--window-threads` on the
//! CLI).  Results are deterministic regardless of scheduling — each window
//! writes its own slot and the stitch/merge walks windows in plan order, so
//! a parallel run is identical to the serial one (module tests assert it).
//!
//! The linear-interpolation plane imputes only between a window's first and
//! last *annotated* markers (that is its model, on windows as on whole
//! chromosomes), so windowing an interp workload is only full-coverage when
//! window boundaries land on the chip grid.  Multi-window interp plans are
//! therefore **validated up front** ([`WindowPlan::validate_interp_coverage`])
//! and a plan whose cores aren't covered is a hard error with a
//! fix-your-geometry message — never silent partial coverage.  The dense
//! planes (baseline/rank1/event/xla) have no such constraint.

use std::sync::Mutex;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::model::accuracy;
use crate::model::panel::TargetHaplotype;
use crate::session::{EngineSpec, ImputeReport, ImputeSession, Workload};

/// One marker window: `[start, end)` is what an engine sees, `[core_start,
/// core_end)` is the sub-interval whose dosages the stitcher keeps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MarkerWindow {
    pub start: usize,
    pub end: usize,
    pub core_start: usize,
    pub core_end: usize,
}

impl MarkerWindow {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }
}

/// A full slicing of `0..n_mark` into overlapping windows with disjoint
/// cores.  Construction is total over its domain: any `window_len >= 2` and
/// `overlap < window_len` yields a valid plan for any `n_mark >= 2`.
#[derive(Clone, Debug)]
pub struct WindowPlan {
    n_mark: usize,
    windows: Vec<MarkerWindow>,
}

impl WindowPlan {
    /// Plan windows of `window_len` markers overlapping by `overlap`.
    ///
    /// `window_len` clamps to the panel width (a window cannot exceed the
    /// chromosome), so `window_len >= n_mark` yields the single-window plan.
    /// Errors, not panics: window geometry arrives from CLI flags and
    /// request fields.
    pub fn new(n_mark: usize, window_len: usize, overlap: usize) -> Result<WindowPlan, String> {
        if n_mark < 2 {
            return Err(format!("cannot window a {n_mark}-marker panel (need >= 2)"));
        }
        if window_len < 2 {
            return Err(format!("window length {window_len} too small (need >= 2)"));
        }
        let w = window_len.min(n_mark);
        if overlap >= w {
            return Err(format!(
                "overlap {overlap} must be smaller than the effective window length {w}"
            ));
        }
        let stride = w - overlap;
        let mut spans: Vec<(usize, usize)> = Vec::new();
        let mut start = 0usize;
        loop {
            let end = start + w;
            spans.push((start, end));
            if end >= n_mark {
                break;
            }
            // Keep full-length windows: when the next regular stride would
            // overshoot, shift it left to end exactly at the chromosome end
            // (the overlap with the previous window grows, never shrinks).
            start = if start + stride + w > n_mark {
                n_mark - w
            } else {
                start + stride
            };
        }
        // Core boundaries: midpoints of consecutive windows' overlaps.
        let mut windows = Vec::with_capacity(spans.len());
        for (i, &(start, end)) in spans.iter().enumerate() {
            let core_start = if i == 0 {
                0
            } else {
                (start + spans[i - 1].1) / 2
            };
            let core_end = if i + 1 == spans.len() {
                n_mark
            } else {
                (spans[i + 1].0 + end) / 2
            };
            windows.push(MarkerWindow {
                start,
                end,
                core_start,
                core_end,
            });
        }
        Ok(WindowPlan { n_mark, windows })
    }

    pub fn n_mark(&self) -> usize {
        self.n_mark
    }

    pub fn windows(&self) -> &[MarkerWindow] {
        &self.windows
    }

    pub fn len(&self) -> usize {
        self.windows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// Check that every window of a multi-window plan fully covers its core
    /// on the linear-interpolation plane, whose model imputes only between
    /// a window's first and last *annotated* markers (`anchors` is the
    /// shared chip grid, ascending absolute marker indices).  Partial
    /// coverage would silently leave core markers unimputed, so
    /// [`run_windowed`] turns it into a hard error for interp runs.
    ///
    /// Markers outside the plan-wide anchor span `[anchors[0],
    /// anchors.last()]` are exempt: no interp run — windowed or not — ever
    /// covers them (the unwindowed plane's documented head/tail behaviour),
    /// so they are not a *windowing* defect and must not make every
    /// geometry unsatisfiable on grids that stop short of the panel ends.
    pub fn validate_interp_coverage(&self, anchors: &[usize]) -> Result<(), String> {
        let (Some(&span_first), Some(&span_last)) = (anchors.first(), anchors.last()) else {
            return Err("interp windowing: targets have no annotated markers".into());
        };
        for (i, w) in self.windows.iter().enumerate() {
            let first = anchors.iter().copied().find(|&a| a >= w.start && a < w.end);
            let last = anchors
                .iter()
                .rev()
                .copied()
                .find(|&a| a >= w.start && a < w.end);
            let in_window = anchors
                .iter()
                .filter(|&&a| a >= w.start && a < w.end)
                .count();
            let (Some(first), Some(last)) = (first, last) else {
                return Err(format!(
                    "interp window {i} [{}, {}) contains no annotated marker; \
                     align --window/--overlap to the chip grid",
                    w.start, w.end
                ));
            };
            if in_window < 2 {
                return Err(format!(
                    "interp window {i} [{}, {}) contains only one annotated marker \
                     (interpolation needs >= 2); align --window/--overlap to the chip grid",
                    w.start, w.end
                ));
            }
            // The part of this window's core any interp run could cover.
            let need_start = w.core_start.max(span_first);
            let need_end = w.core_end.min(span_last + 1);
            if need_start < need_end && (first > need_start || last + 1 < need_end) {
                return Err(format!(
                    "interp window {i} [{}, {}) covers only markers [{first}, {last}] \
                     but its core needs [{need_start}, {need_end}): the \
                     linear-interpolation plane imputes only between a window's first \
                     and last annotated markers, so this plan would silently skip core \
                     markers — choose --window/--overlap so every window edge lands on \
                     the chip (annotation) grid",
                    w.start, w.end
                ));
            }
        }
        Ok(())
    }

    /// Assemble the sub-workload one window sees: panel columns `[start,
    /// end)` and every target's observations sliced to match.  Contiguous
    /// `select_markers` ranges pass genetic distances through bit-exactly,
    /// so a single-window plan reproduces the original workload.  Withheld
    /// truth is *not* propagated — per-window accuracy over a fragment is
    /// meaningless; [`run_windowed`] re-scores on the stitched result.
    pub fn slice_workload(&self, full: &Workload, window: &MarkerWindow) -> Workload {
        let marks: Vec<usize> = (window.start..window.end).collect();
        let panel = full.panel().select_markers(&marks);
        let targets: Vec<TargetHaplotype> = full
            .targets()
            .iter()
            .map(|t| TargetHaplotype::new(t.obs[window.start..window.end].to_vec()))
            .collect();
        Workload::from_parts(panel, targets)
    }
}

/// Merge per-window dosage matrices into one full-width matrix: each
/// window contributes exactly its core columns.  `per_window[i]` must be
/// the dosages of window `i` (`[target][marker-within-window]`).
pub fn stitch(plan: &WindowPlan, per_window: &[Vec<Vec<f32>>]) -> Result<Vec<Vec<f32>>, String> {
    if per_window.len() != plan.len() {
        return Err(format!(
            "stitch: {} dosage sets for a {}-window plan",
            per_window.len(),
            plan.len()
        ));
    }
    let n_targets = per_window.first().map_or(0, Vec::len);
    let mut full = vec![vec![0.0f32; plan.n_mark()]; n_targets];
    for (i, (win, dosages)) in plan.windows().iter().zip(per_window).enumerate() {
        if dosages.len() != n_targets {
            return Err(format!(
                "stitch: window {i} has {} targets, window 0 has {n_targets}",
                dosages.len()
            ));
        }
        for (t, row) in dosages.iter().enumerate() {
            if row.len() != win.len() {
                return Err(format!(
                    "stitch: window {i} target {t} has {} markers, window spans {}",
                    row.len(),
                    win.len()
                ));
            }
            full[t][win.core_start..win.core_end]
                .copy_from_slice(&row[win.core_start - win.start..win.core_end - win.start]);
        }
    }
    Ok(full)
}

/// Run a workload window-by-window on `spec` and stitch one report (serial
/// windows — [`run_windowed_threads`] with one thread).
pub fn run_windowed<F>(
    full: &Workload,
    plan: &WindowPlan,
    spec: EngineSpec,
    configure: F,
) -> Result<ImputeReport, String>
where
    F: Fn(ImputeSession) -> ImputeSession + Sync,
{
    run_windowed_threads(full, plan, spec, 1, configure)
}

/// Run a workload window-by-window on `spec`, fanning the windows out over
/// up to `window_threads` std threads, and stitch one report.
///
/// The engine plane is `spec` — it is threaded explicitly so engine-specific
/// plan validation (the interp coverage check) happens before any window
/// runs.  `configure` applies the remaining knobs to each per-window session
/// (it receives a fresh `ImputeSession::new(window_workload)` and must
/// return the configured builder; the engine selection is applied *after*
/// it, so `spec` is authoritative) — the same closure shape the CLI builds
/// from its flags.  Under `window_threads > 1` the closure is called from
/// worker threads.  The merged report carries the stitched dosages, summed
/// host/simulated timings, accumulated DES counters, accuracy re-scored
/// against the full workload's truth, and `windows = plan.len()`.
///
/// Windows are independent problems, so the fan-out changes wall-clock
/// only: each window writes its own result slot and stitching/merging walks
/// windows in plan order, making the report deterministic for any thread
/// count (on error, the lowest-indexed failing window's error is returned).
pub fn run_windowed_threads<F>(
    full: &Workload,
    plan: &WindowPlan,
    spec: EngineSpec,
    window_threads: usize,
    configure: F,
) -> Result<ImputeReport, String>
where
    F: Fn(ImputeSession) -> ImputeSession + Sync,
{
    // Engine-specific plan validation: the interp plane's coverage caveat is
    // a hard error on multi-window plans (a single-window plan is exactly
    // the unwindowed run, whose anchor-span behaviour is documented).
    validate_windowed(full, plan, spec)?;

    let n = plan.len();
    let threads = window_threads.max(1).min(n);
    let run_window = |i: usize| -> Result<ImputeReport, String> {
        let win = &plan.windows()[i];
        configure(ImputeSession::new(plan.slice_workload(full, win)))
            .engine(spec)
            .run()
            .map_err(|e| format!("window {i} ([{}, {})): {e}", win.start, win.end))
    };
    let mut reports: Vec<ImputeReport> = Vec::with_capacity(n);
    if threads <= 1 {
        for i in 0..n {
            reports.push(run_window(i)?);
        }
    } else {
        // Work-stealing over window indices; every claimed index fills its
        // own slot, so completion order never affects the result.
        #[allow(clippy::type_complexity)]
        let slots: Vec<Mutex<Option<Result<ImputeReport, String>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|sc| {
            for _ in 0..threads {
                sc.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let result = run_window(i);
                    *slots[i].lock().expect("window slot poisoned") = Some(result);
                });
            }
        });
        for slot in slots {
            let result = slot
                .into_inner()
                .expect("window slot poisoned")
                .expect("every window index was claimed");
            reports.push(result?);
        }
    }
    stitch_reports(full, plan, reports)
}

/// Validate a windowed run's inputs before any window executes — shared by
/// [`run_windowed_threads`] and the streamed pipeline
/// ([`crate::genomics::stream::run_streamed`]).
pub(crate) fn validate_windowed(
    full: &Workload,
    plan: &WindowPlan,
    spec: EngineSpec,
) -> Result<(), String> {
    if plan.n_mark() != full.panel().n_mark() {
        return Err(format!(
            "window plan covers {} markers, workload has {}",
            plan.n_mark(),
            full.panel().n_mark()
        ));
    }
    if full.n_targets() == 0 {
        return Err("workload has no targets".into());
    }
    if plan.len() > 1 && spec == EngineSpec::Interp {
        let anchors = full.targets()[0].annotated();
        plan.validate_interp_coverage(&anchors)?;
    }
    Ok(())
}

/// Stitch per-window reports (in plan order) into one merged report —
/// shared by [`run_windowed_threads`] and the streamed pipeline, so a
/// streamed run is bit-identical to a windowed one by construction.
pub(crate) fn stitch_reports(
    full: &Workload,
    plan: &WindowPlan,
    mut reports: Vec<ImputeReport>,
) -> Result<ImputeReport, String> {
    // Drain the per-window dosages rather than cloning them: on the
    // chromosome-scale runs windowing exists for, the dosage matrices are
    // the dominant allocation.
    let per_window: Vec<Vec<Vec<f32>>> = reports
        .iter_mut()
        .map(|r| std::mem::take(&mut r.dosages))
        .collect();
    let dosages = stitch(plan, &per_window)?;
    drop(per_window);

    let accuracy = full
        .truth()
        .map(|truth| accuracy::score_set(&dosages, truth, full.targets()));

    let mut merged = reports.remove(0);
    for r in &mut reports {
        merged.host_seconds += r.host_seconds;
        merged.n_batches += r.n_batches;
        if let Some(s) = r.sim_seconds {
            *merged.sim_seconds.get_or_insert(0.0) += s;
        }
        if let Some(m) = &r.metrics {
            match &mut merged.metrics {
                None => merged.metrics = Some(m.clone()),
                Some(acc) => acc.absorb(m),
            }
        }
        // Traced runs: each window's trace becomes its own segment(s) in
        // the merged trace, in plan order — `impute --trace` on a windowed
        // run covers the whole chromosome.
        if let Some(t) = r.trace.take() {
            match &mut merged.trace {
                None => merged.trace = Some(t),
                Some(acc) => acc.absorb(t),
            }
        }
    }
    merged.n_mark = full.panel().n_mark();
    merged.dosages = dosages;
    merged.accuracy = accuracy;
    merged.provenance = full.provenance().copied();
    merged.windows = Some(plan.len());
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{EngineSpec, max_abs_dosage_diff};
    use crate::workload::panelgen::PanelConfig;

    fn plan(n_mark: usize, w: usize, v: usize) -> WindowPlan {
        WindowPlan::new(n_mark, w, v).unwrap()
    }

    fn workload_ratio(n_mark: usize, n_targets: usize, annot_ratio: f64) -> Workload {
        Workload::synthetic(
            &PanelConfig {
                n_hap: 8,
                n_mark,
                maf: 0.2,
                annot_ratio,
                seed: 77,
                ..PanelConfig::default()
            },
            n_targets,
        )
    }

    fn workload(n_mark: usize, n_targets: usize) -> Workload {
        workload_ratio(n_mark, n_targets, 0.25)
    }

    #[test]
    fn plan_covers_and_partitions() {
        let p = plan(40, 20, 10);
        assert_eq!(p.len(), 3);
        assert_eq!(
            p.windows()[0],
            MarkerWindow { start: 0, end: 20, core_start: 0, core_end: 15 }
        );
        assert_eq!(
            p.windows()[1],
            MarkerWindow { start: 10, end: 30, core_start: 15, core_end: 25 }
        );
        assert_eq!(
            p.windows()[2],
            MarkerWindow { start: 20, end: 40, core_start: 25, core_end: 40 }
        );
    }

    #[test]
    fn ragged_tail_shifts_the_last_window() {
        // 45 markers, windows of 20, stride 10: the last window would start
        // at 30 and overshoot, so it shifts to [25, 45) — still 20 long.
        let p = plan(45, 20, 10);
        let last = *p.windows().last().unwrap();
        assert_eq!((last.start, last.end), (25, 45));
        assert!(p.windows().iter().all(|w| w.len() == 20));
        assert_eq!(last.core_end, 45);
    }

    #[test]
    fn single_window_when_panel_fits() {
        for w in [40, 64, 1000] {
            let p = plan(40, w, 8);
            assert_eq!(p.len(), 1);
            assert_eq!(
                p.windows()[0],
                MarkerWindow { start: 0, end: 40, core_start: 0, core_end: 40 }
            );
        }
    }

    #[test]
    fn zero_overlap_abuts() {
        let p = plan(40, 10, 0);
        assert_eq!(p.len(), 4);
        for (i, w) in p.windows().iter().enumerate() {
            assert_eq!((w.start, w.end), (10 * i, 10 * i + 10));
            assert_eq!((w.core_start, w.core_end), (10 * i, 10 * i + 10));
        }
    }

    #[test]
    fn bad_geometry_is_an_error() {
        assert!(WindowPlan::new(1, 4, 0).is_err());
        assert!(WindowPlan::new(40, 1, 0).is_err());
        assert!(WindowPlan::new(40, 8, 8).is_err());
        assert!(WindowPlan::new(40, 8, 12).is_err());
        // Overlap checked against the *effective* (clamped) length.
        assert!(WindowPlan::new(10, 100, 50).is_err());
        assert!(WindowPlan::new(10, 100, 5).is_ok());
    }

    #[test]
    fn sliced_workload_matches_columns() {
        let wl = workload(30, 2);
        let p = plan(30, 12, 4);
        let win = p.windows()[1];
        let sub = p.slice_workload(&wl, &win);
        assert_eq!(sub.panel().n_mark(), win.len());
        assert_eq!(sub.n_targets(), 2);
        assert!(sub.truth().is_none());
        for m in 0..win.len() {
            assert_eq!(sub.panel().column(m), wl.panel().column(win.start + m));
            // Interior distances pass through bit-exactly.
            if m > 0 {
                assert_eq!(
                    sub.panel().gen_dist(m).to_bits(),
                    wl.panel().gen_dist(win.start + m).to_bits()
                );
            }
            assert_eq!(sub.targets()[0].obs[m], wl.targets()[0].obs[win.start + m]);
        }
        assert_eq!(sub.panel().gen_dist(0), 0.0);
    }

    #[test]
    fn stitch_takes_each_core_from_its_window() {
        let p = plan(40, 20, 10);
        // Fill each window's dosages with its own index; the stitched row
        // must read the owning window's index at every marker.
        let per: Vec<Vec<Vec<f32>>> = (0..p.len())
            .map(|i| vec![vec![i as f32; p.windows()[i].len()]; 2])
            .collect();
        let full = stitch(&p, &per).unwrap();
        assert_eq!(full.len(), 2);
        for (i, w) in p.windows().iter().enumerate() {
            for m in w.core_start..w.core_end {
                assert_eq!(full[0][m], i as f32, "marker {m}");
            }
        }
        // Shape mismatches are errors.
        assert!(stitch(&p, &per[..2]).is_err());
        let mut ragged = per.clone();
        ragged[1][0].pop();
        assert!(stitch(&p, &ragged).is_err());
    }

    #[test]
    fn single_window_run_is_bit_identical_to_plain_session() {
        let wl = workload(21, 2);
        let p = plan(21, 64, 4);
        let windowed = run_windowed(&wl, &p, EngineSpec::Event, |s| {
            s.boards(1).states_per_thread(8)
        })
        .unwrap();
        let plain = ImputeSession::new(wl.clone())
            .engine(EngineSpec::Event)
            .boards(1)
            .states_per_thread(8)
            .run()
            .unwrap();
        assert_eq!(windowed.dosages, plain.dosages);
        assert_eq!(windowed.windows, Some(1));
        assert!(windowed.accuracy.is_some(), "truth re-scored on the stitch");
    }

    #[test]
    fn windowed_engines_agree_and_track_the_full_run() {
        let wl = workload(40, 2);
        // Starts (0, 7, 14) avoid the 1-in-4 annotation grid: a window
        // applies no emission at its first marker, so starting on an anchor
        // would discard that anchor's evidence.
        let p = plan(40, 26, 19);
        let base = run_windowed(&wl, &p, EngineSpec::Baseline, |s| s).unwrap();
        let event = run_windowed(&wl, &p, EngineSpec::Event, |s| {
            s.boards(1).states_per_thread(8)
        })
        .unwrap();
        // Engine equivalence survives windowing (same tolerance as unwindowed).
        assert!(max_abs_dosage_diff(&base.dosages, &event.dosages) <= 1e-3);
        // Cores are buffered by overlap/2 = 8 markers, so the stitched run
        // tracks the full run closely (window boundary conditions decay).
        let full = ImputeSession::new(wl.clone())
            .engine(EngineSpec::Baseline)
            .run()
            .unwrap();
        let drift = max_abs_dosage_diff(&base.dosages, &full.dosages);
        assert!(drift < 0.2, "windowed drifted {drift} from the full run");
        // Accounting merges across windows.
        assert_eq!(event.windows, Some(p.len()));
        assert!(event.sim_seconds.unwrap() > 0.0);
        assert!(event.metrics.unwrap().sends > 0);
        assert_eq!(base.n_mark, 40);
        assert_eq!(base.dosages[0].len(), 40);
    }

    #[test]
    fn window_threads_do_not_change_the_stitched_report() {
        let wl = workload(40, 2);
        let p = plan(40, 26, 19);
        let cfg = |s: ImputeSession| s.boards(1).states_per_thread(8);
        let serial = run_windowed(&wl, &p, EngineSpec::Event, cfg).unwrap();
        let parallel = run_windowed_threads(&wl, &p, EngineSpec::Event, 3, cfg).unwrap();
        assert_eq!(serial.dosages, parallel.dosages, "fan-out changed numerics");
        assert_eq!(serial.windows, parallel.windows);
        let (sm, pm) = (serial.metrics.unwrap(), parallel.metrics.unwrap());
        assert_eq!(sm.sends, pm.sends);
        assert_eq!(sm.sim_cycles, pm.sim_cycles);
        assert_eq!(sm.step_durations, pm.step_durations, "merge order must be plan order");
        // Oversubscription clamps to the window count.
        let many = run_windowed_threads(&wl, &p, EngineSpec::Event, 64, cfg).unwrap();
        assert_eq!(serial.dosages, many.dosages);
    }

    #[test]
    fn misaligned_interp_windows_are_hard_errors() {
        // Chip grid every 10th marker (0,10,20,30,40); window starts at 18
        // and 20 leave the second window's core start (19) ahead of its
        // first anchor (20) — previously silent partial coverage.
        let wl = workload_ratio(41, 1, 0.1);
        let bad = plan(41, 21, 3);
        let err = run_windowed(&wl, &bad, EngineSpec::Interp, |s| {
            s.boards(1).states_per_thread(1)
        })
        .unwrap_err();
        assert!(err.contains("chip"), "unexpected message: {err}");
        // The event plane has no grid constraint: the same plan runs.
        let ok = run_windowed(&wl, &bad, EngineSpec::Event, |s| {
            s.boards(1).states_per_thread(8)
        });
        assert!(ok.is_ok(), "{ok:?}");
    }

    #[test]
    fn aligned_interp_windows_validate_and_run() {
        let wl = workload_ratio(41, 2, 0.1);
        // Spans [0,21) and [20,41) split cores at marker 20 — every core is
        // inside its window's [first, last] anchor span.
        let p = plan(41, 21, 1);
        let anchors = wl.targets()[0].annotated();
        p.validate_interp_coverage(&anchors).unwrap();
        let report = run_windowed_threads(&wl, &p, EngineSpec::Interp, 2, |s| {
            s.boards(1).states_per_thread(1)
        })
        .unwrap();
        assert_eq!(report.windows, Some(2));
        assert_eq!(report.dosages[0].len(), 41);
        assert!(report.dosages[0].iter().all(|d| d.is_finite()));
    }

    #[test]
    fn interp_coverage_validator_rejects_anchorless_windows() {
        // A fabricated sparse grid: windows [10,20) hold no anchor at all.
        let p = plan(40, 10, 0);
        let err = p.validate_interp_coverage(&[0, 5, 25, 35, 39]).unwrap_err();
        assert!(err.contains("annotated"), "{err}");
        // A one-anchor window is rejected too (interpolation needs >= 2).
        let err = p.validate_interp_coverage(&[0, 9, 15, 25, 35, 39]).unwrap_err();
        assert!(err.contains(">= 2"), "{err}");
        // An empty grid is its own error.
        let err = p.validate_interp_coverage(&[]).unwrap_err();
        assert!(err.contains("no annotated"), "{err}");
    }

    #[test]
    fn interp_coverage_exempts_markers_outside_the_anchor_span() {
        // A chip grid that stops short of the panel ends: markers before 4
        // and after 34 are uncovered by ANY interp run (windowed or not),
        // so a plan whose interior seams sit on the grid must validate.
        let p = plan(40, 20, 10);
        p.validate_interp_coverage(&[4, 9, 14, 19, 24, 29, 34]).unwrap();
        // ...but an interior gap is still a hard error.
        let err = p
            .validate_interp_coverage(&[4, 9, 29, 34])
            .unwrap_err();
        assert!(err.contains("chip"), "{err}");
    }

    #[test]
    fn plan_mismatch_and_empty_workload_are_errors() {
        let wl = workload(30, 1);
        let p = plan(40, 20, 10);
        assert!(run_windowed(&wl, &p, EngineSpec::Baseline, |s| s).is_err());
        let empty = Workload::from_parts(wl.panel().clone(), Vec::new());
        let p30 = plan(30, 20, 10);
        assert!(run_windowed(&empty, &p30, EngineSpec::Baseline, |s| s).is_err());
    }
}
