//! Real-data panels: VCF ingestion, bit-packed storage and windowed
//! chunking — the front door that lets every compute plane run the paper's
//! *actual* workload (impute targets against a real reference panel) instead
//! of only `workload::panelgen` synthetics.
//!
//! Three layers, each usable on its own:
//!
//! * [`vcf`] — a zero-dependency parser for the VCF subset imputation
//!   reference panels actually use (bi-allelic, phased GT records on one
//!   chromosome).  Produces a [`crate::model::panel::ReferencePanel`] plus
//!   per-site metadata ([`vcf::Site`]: CHROM/POS/ID and allele frequency),
//!   with strict per-line error reporting — a malformed panel must fail
//!   loudly at ingest, never silently skew dosages.
//! * [`gmap`] — PLINK/HapMap genetic-map parsing with piecewise-linear
//!   position→cM interpolation ([`gmap::GeneticMap`]).  `panel ingest
//!   --genetic-map PATH` applies it at ingest, replacing the parser's flat
//!   1 cM/Mb conversion with real hotspot structure.
//! * [`packed`] — [`packed::PackedPanel`], the haplotype matrix at **1 bit
//!   per allele** (8x smaller than the `Vec<u8>` working representation)
//!   with a checksummed on-disk format (`.ppnl`) and a lossless
//!   [`ReferencePanel`](crate::model::panel::ReferencePanel) round-trip.
//!   This is what `poets-impute panel ingest` writes and what `packed:`
//!   registry specs load.
//! * [`window`] — chromosome-scale chunking: slice a panel into overlapping
//!   marker windows ([`window::WindowPlan`]), run any engine per window
//!   through the unified session pipeline, and stitch the per-window dosages
//!   back together ([`window::run_windowed`]), resolving overlaps at the
//!   window midpoint.  This is how a workload larger than one graph build
//!   runs on the event planes.
//! * [`stream`] — the streaming execution of a window plan
//!   ([`stream::run_streamed`]): windows are sliced on a builder thread and
//!   drained through the engine one at a time with rendezvous-channel
//!   backpressure, so the peak working set is two windows (and one
//!   application graph) regardless of chromosome length — bit-identical to
//!   the materialised runner, `impute --stream` on the CLI.
//!
//! Wiring: [`crate::serve::PanelRegistry`] resolves `vcf:<path>` and
//! `packed:<path>` specs alongside `synth:`, the CLI gains
//! `panel ingest`/`panel info`, and `impute --panel <spec> --window W`
//! drives the windowed path end to end (see `tests/real_panel_e2e.rs`).

pub mod gmap;
pub mod packed;
pub mod stream;
pub mod vcf;
pub mod window;

pub use gmap::GeneticMap;
pub use packed::PackedPanel;
pub use stream::run_streamed;
pub use vcf::{Site, VcfOptions, VcfPanel};
pub use window::{MarkerWindow, WindowPlan, run_windowed, run_windowed_threads, stitch};
