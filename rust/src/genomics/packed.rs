//! [`PackedPanel`] — the reference panel at 1 bit per allele, with a
//! checksummed on-disk format.
//!
//! The working representation ([`ReferencePanel`]) spends one byte per
//! allele because the compute planes index it hot; at rest that is 8x more
//! memory and disk than a diallelic matrix needs.  `PackedPanel` stores the
//! same matrix bit-packed (row-major, LSB-first within each byte, rows
//! padded to whole bytes with zero bits) and round-trips losslessly:
//! `PackedPanel::from_panel(&p).to_panel()` reproduces `p` exactly,
//! genetic distances bit-for-bit (they are stored as raw IEEE-754 doubles).
//!
//! ## The `.ppnl` format (version 1)
//!
//! Everything is little-endian.  Layout:
//!
//! ```text
//! offset  size           field
//! 0       8              magic: the ASCII bytes "POETSPNL"
//! 8       4              format version (u32) = 1
//! 12      4              flags (u32): bit 0 = site metadata present
//! 16      8              n_hap  (u64 header)
//! 24      8              n_mark (u64 header)
//! 32      8 x n_mark     genetic distances (f64 bit patterns)
//! ...     r x n_hap      allele bits, r = ceil(n_mark / 8) bytes per row
//! ...     sites          (only when flags bit 0 is set) n_mark records:
//!                          u16 chrom length + bytes, u16 id length + bytes,
//!                          u64 pos, f64 allele-1 frequency (chrom/id are
//!                          capped at 65,535 bytes — enforced at VCF ingest)
//! ...     8              FNV-1a 64 checksum of every preceding byte
//! ```
//!
//! Decoding is strict and total: wrong magic, unknown version, truncated or
//! oversized payloads, non-canonical padding bits, invalid genetic
//! distances and checksum mismatches are all recoverable `Err`s (panel
//! files reach the serve layer via untrusted `packed:` request specs, so a
//! corrupt file must never panic a worker).

use crate::model::panel::ReferencePanel;

use super::vcf::{Site, VcfPanel};

/// Magic prefix of every `.ppnl` file.
pub const MAGIC: [u8; 8] = *b"POETSPNL";
/// Current (only) format version.
pub const VERSION: u32 = 1;
/// Conventional file extension.
pub const EXTENSION: &str = "ppnl";

const FLAG_SITES: u32 = 1;
/// Fixed-size prefix: magic + version + flags + n_hap + n_mark.
const HEADER_BYTES: usize = 8 + 4 + 4 + 8 + 8;

/// A reference panel bit-packed to 1 bit per allele, plus the genetic
/// distances and (when ingested from VCF) per-site metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedPanel {
    n_hap: usize,
    n_mark: usize,
    /// Bytes per haplotype row: `ceil(n_mark / 8)`.
    row_bytes: usize,
    /// Row-major packed alleles; bit `m % 8` of byte `h * row_bytes + m / 8`
    /// is the allele of haplotype `h` at marker `m`.  Padding bits are zero.
    bits: Vec<u8>,
    gen_dist: Vec<f64>,
    sites: Option<Vec<Site>>,
}

impl PackedPanel {
    /// Pack a working panel (no site metadata).
    pub fn from_panel(panel: &ReferencePanel) -> PackedPanel {
        Self::pack(panel, None)
    }

    /// Pack a VCF-ingested panel, keeping its site metadata.
    pub fn from_vcf(vcf: &VcfPanel) -> PackedPanel {
        Self::pack(&vcf.panel, Some(vcf.sites.clone()))
    }

    fn pack(panel: &ReferencePanel, sites: Option<Vec<Site>>) -> PackedPanel {
        if let Some(s) = &sites {
            assert_eq!(s.len(), panel.n_mark(), "site metadata length mismatch");
        }
        let (n_hap, n_mark) = (panel.n_hap(), panel.n_mark());
        let row_bytes = n_mark.div_ceil(8);
        let mut bits = vec![0u8; n_hap * row_bytes];
        for h in 0..n_hap {
            let row = &mut bits[h * row_bytes..(h + 1) * row_bytes];
            for m in 0..n_mark {
                // The panel guarantees alleles are 0/1.
                row[m / 8] |= panel.allele(h, m) << (m % 8);
            }
        }
        PackedPanel {
            n_hap,
            n_mark,
            row_bytes,
            bits,
            gen_dist: panel.gen_dists().to_vec(),
            sites,
        }
    }

    #[inline]
    pub fn n_hap(&self) -> usize {
        self.n_hap
    }

    #[inline]
    pub fn n_mark(&self) -> usize {
        self.n_mark
    }

    #[inline]
    pub fn allele(&self, hap: usize, mark: usize) -> u8 {
        debug_assert!(hap < self.n_hap && mark < self.n_mark);
        (self.bits[hap * self.row_bytes + mark / 8] >> (mark % 8)) & 1
    }

    /// Site metadata, when the panel was ingested from VCF.
    pub fn sites(&self) -> Option<&[Site]> {
        self.sites.as_deref()
    }

    /// Bytes the packed allele matrix occupies (the 8x-smaller number; the
    /// working panel spends `n_hap * n_mark` bytes on the same data).
    pub fn packed_allele_bytes(&self) -> usize {
        self.bits.len()
    }

    /// Unpack to the working representation.  Lossless: alleles and genetic
    /// distances reproduce the packed source exactly.
    pub fn to_panel(&self) -> ReferencePanel {
        let mut alleles = Vec::with_capacity(self.n_hap * self.n_mark);
        for h in 0..self.n_hap {
            for m in 0..self.n_mark {
                alleles.push(self.allele(h, m));
            }
        }
        ReferencePanel::new(self.n_hap, self.n_mark, alleles, self.gen_dist.clone())
    }

    /// Serialise to the `.ppnl` byte format (see module docs).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            HEADER_BYTES + self.gen_dist.len() * 8 + self.bits.len() + 8,
        );
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        let flags = if self.sites.is_some() { FLAG_SITES } else { 0 };
        out.extend_from_slice(&flags.to_le_bytes());
        out.extend_from_slice(&(self.n_hap as u64).to_le_bytes());
        out.extend_from_slice(&(self.n_mark as u64).to_le_bytes());
        for &d in &self.gen_dist {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out.extend_from_slice(&self.bits);
        if let Some(sites) = &self.sites {
            for s in sites {
                encode_str(&mut out, &s.chrom);
                encode_str(&mut out, &s.id);
                out.extend_from_slice(&s.pos.to_le_bytes());
                out.extend_from_slice(&s.af.to_le_bytes());
            }
        }
        let sum = fnv1a64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse the `.ppnl` byte format.  Strict: every structural defect is a
    /// descriptive error, and trailing bytes beyond the checksum are
    /// rejected.
    pub fn decode(bytes: &[u8]) -> Result<PackedPanel, String> {
        if bytes.len() < HEADER_BYTES + 8 {
            return Err(format!(
                "truncated: {} bytes is smaller than any valid .ppnl",
                bytes.len()
            ));
        }
        let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().expect("8 bytes"));
        let computed = fnv1a64(body);
        if stored != computed {
            return Err(format!(
                "checksum mismatch (stored {stored:#018x}, computed {computed:#018x}) — \
                 the file is corrupt or was not written by `panel ingest`"
            ));
        }
        let mut r = Reader { bytes: body, pos: 0 };
        let magic = r.take(8)?;
        if magic != MAGIC {
            return Err(format!("bad magic {magic:?} (expected {MAGIC:?})"));
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(format!(
                "unsupported format version {version} (this build reads version {VERSION})"
            ));
        }
        let flags = r.u32()?;
        if flags & !FLAG_SITES != 0 {
            return Err(format!("unknown flag bits {flags:#x}"));
        }
        let n_hap = r.u64()? as usize;
        let n_mark = r.u64()? as usize;
        if n_hap < 2 || n_mark < 2 {
            return Err(format!(
                "panel shape {n_hap}x{n_mark} is too small (need >= 2 haplotypes and markers)"
            ));
        }
        // Reject absurd headers before sizing any allocation from them.
        let row_bytes = n_mark.div_ceil(8);
        let need = n_mark
            .checked_mul(8)
            .and_then(|g| g.checked_add(n_hap.checked_mul(row_bytes)?))
            .ok_or("panel shape overflows")?;
        if need > body.len() {
            return Err(format!(
                "truncated: header promises {need} payload bytes, file has {}",
                body.len() - r.pos
            ));
        }

        let mut gen_dist = Vec::with_capacity(n_mark);
        for m in 0..n_mark {
            let d = f64::from_bits(u64::from_le_bytes(
                r.take(8)?.try_into().expect("8 bytes"),
            ));
            let valid = if m == 0 { d == 0.0 } else { d > 0.0 && d.is_finite() };
            if !valid {
                return Err(format!("invalid genetic distance {d} at marker {m}"));
            }
            gen_dist.push(d);
        }
        let bits = r.take(n_hap * row_bytes)?.to_vec();
        // Canonical encoding: padding bits beyond n_mark must be zero, so
        // byte equality (and the checksum) is a function of the panel alone.
        if n_mark % 8 != 0 {
            let mask = !0u8 << (n_mark % 8);
            for h in 0..n_hap {
                let last = bits[h * row_bytes + row_bytes - 1];
                if last & mask != 0 {
                    return Err(format!("non-zero padding bits in haplotype {h}"));
                }
            }
        }
        let sites = if flags & FLAG_SITES != 0 {
            let mut sites = Vec::with_capacity(n_mark);
            for m in 0..n_mark {
                let chrom = r.string().map_err(|e| format!("site {m} chrom: {e}"))?;
                let id = r.string().map_err(|e| format!("site {m} id: {e}"))?;
                let pos = r.u64().map_err(|e| format!("site {m}: {e}"))?;
                let af = f64::from_bits(u64::from_le_bytes(
                    r.take(8).map_err(|e| format!("site {m}: {e}"))?.try_into().expect("8 bytes"),
                ));
                if !(0.0..=1.0).contains(&af) {
                    return Err(format!("site {m}: allele frequency {af} out of [0,1]"));
                }
                sites.push(Site { chrom, pos, id, af });
            }
            Some(sites)
        } else {
            None
        };
        if r.pos != body.len() {
            return Err(format!(
                "{} trailing bytes after the payload",
                body.len() - r.pos
            ));
        }
        Ok(PackedPanel {
            n_hap,
            n_mark,
            row_bytes,
            bits,
            gen_dist,
            sites,
        })
    }

    /// Read just the fixed header of a `.ppnl` file: `(n_hap, n_mark)`.
    ///
    /// 32 bytes of I/O and no payload parsing — the cheap pre-admission
    /// check serve-facing loaders run before committing to a full
    /// [`PackedPanel::read`] (which still validates everything, checksum
    /// included).
    pub fn peek_shape(path: &str) -> Result<(usize, usize), String> {
        use std::io::Read;
        let mut head = [0u8; HEADER_BYTES];
        let mut file =
            std::fs::File::open(path).map_err(|e| format!("cannot read {path}: {e}"))?;
        file.read_exact(&mut head)
            .map_err(|e| format!("{path}: truncated header: {e}"))?;
        if head[0..8] != MAGIC {
            return Err(format!("{path}: bad magic (expected {MAGIC:?})"));
        }
        let version = u32::from_le_bytes(head[8..12].try_into().expect("4 bytes"));
        if version != VERSION {
            return Err(format!("{path}: unsupported format version {version}"));
        }
        let n_hap = u64::from_le_bytes(head[16..24].try_into().expect("8 bytes")) as usize;
        let n_mark = u64::from_le_bytes(head[24..32].try_into().expect("8 bytes")) as usize;
        Ok((n_hap, n_mark))
    }

    /// Write the `.ppnl` file.
    pub fn write(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.encode()).map_err(|e| format!("cannot write {path}: {e}"))
    }

    /// Read a `.ppnl` file.
    pub fn read(path: &str) -> Result<PackedPanel, String> {
        let bytes =
            std::fs::read(path).map_err(|e| format!("cannot read {path}: {e}"))?;
        Self::decode(&bytes).map_err(|e| format!("{path}: {e}"))
    }
}

fn encode_str(out: &mut Vec<u8>, s: &str) {
    // The format stores a u16 length; the VCF parser enforces this limit at
    // ingest ([`super::vcf`]), so overflowing it here means a programming
    // error upstream — fail loudly rather than truncate (a silent cut could
    // split a UTF-8 character and produce a file that fails its own decode).
    assert!(
        s.len() <= u16::MAX as usize,
        "site string of {} bytes exceeds the .ppnl u16 length field",
        s.len()
    );
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// FNV-1a 64 — tiny, dependency-free integrity check (not cryptographic;
/// it guards against truncation and bit rot, not tampering).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Bounds-checked little-endian cursor.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.bytes.len() {
            return Err(format!(
                "truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.bytes.len() - self.pos
            ));
        }
        let slice = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(slice)
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn string(&mut self) -> Result<String, String> {
        let len = u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")) as usize;
        String::from_utf8(self.take(len)?.to_vec()).map_err(|_| "invalid UTF-8".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::panelgen::{PanelConfig, generate_panel};

    fn panel(n_hap: usize, n_mark: usize, seed: u64) -> ReferencePanel {
        generate_panel(&PanelConfig {
            n_hap,
            n_mark,
            maf: 0.3,
            seed,
            ..PanelConfig::default()
        })
    }

    fn assert_same_panel(a: &ReferencePanel, b: &ReferencePanel) {
        assert_eq!(a.n_hap(), b.n_hap());
        assert_eq!(a.n_mark(), b.n_mark());
        for h in 0..a.n_hap() {
            assert_eq!(a.haplotype(h), b.haplotype(h), "haplotype {h}");
        }
        // Bit-exact doubles, not approximate.
        for m in 0..a.n_mark() {
            assert_eq!(a.gen_dist(m).to_bits(), b.gen_dist(m).to_bits(), "d[{m}]");
        }
    }

    #[test]
    fn roundtrip_is_lossless_at_ragged_width() {
        // 21 % 8 != 0: the last byte of each row is padded.
        let p = panel(6, 21, 1);
        let packed = PackedPanel::from_panel(&p);
        assert_eq!(packed.packed_allele_bytes(), 6 * 3);
        assert!(packed.packed_allele_bytes() * 8 >= 6 * 21);
        assert_same_panel(&p, &packed.to_panel());
        // And through the byte format.
        let back = PackedPanel::decode(&packed.encode()).unwrap();
        assert_eq!(back, packed);
        assert_same_panel(&p, &back.to_panel());
    }

    #[test]
    fn roundtrip_with_sites() {
        let p = panel(4, 9, 2);
        let sites: Vec<Site> = (0..9)
            .map(|m| Site {
                chrom: "20".into(),
                pos: 1000 + 100 * m as u64,
                id: if m % 2 == 0 { format!("rs{m}") } else { ".".into() },
                af: p.allele_freq(m),
            })
            .collect();
        let vcf = VcfPanel { panel: p.clone(), sites: sites.clone() };
        let packed = PackedPanel::from_vcf(&vcf);
        let back = PackedPanel::decode(&packed.encode()).unwrap();
        assert_eq!(back.sites(), Some(&sites[..]));
        assert_same_panel(&p, &back.to_panel());
    }

    #[test]
    fn eight_x_smaller_in_the_limit() {
        let p = panel(16, 256, 3);
        let packed = PackedPanel::from_panel(&p);
        // 256 markers pack to exactly 32 bytes/row: an exact 8x.
        assert_eq!(packed.packed_allele_bytes() * 8, 16 * 256);
    }

    #[test]
    fn corrupt_files_are_errors_not_panics() {
        let packed = PackedPanel::from_panel(&panel(4, 11, 4));
        let good = packed.encode();

        // Truncations at every boundary class.
        for cut in [0, 4, HEADER_BYTES - 1, good.len() - 9, good.len() - 1] {
            let e = PackedPanel::decode(&good[..cut]).unwrap_err();
            assert!(
                e.contains("truncated") || e.contains("checksum"),
                "cut {cut}: {e}"
            );
        }
        // A flipped payload byte breaks the checksum.
        let mut flipped = good.clone();
        flipped[HEADER_BYTES + 3] ^= 0x40;
        assert!(PackedPanel::decode(&flipped).unwrap_err().contains("checksum"));
        // Wrong magic (checksum recomputed so the magic check is what trips).
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        let sum = fnv1a64(&bad_magic[..bad_magic.len() - 8]).to_le_bytes();
        let n = bad_magic.len();
        bad_magic[n - 8..].copy_from_slice(&sum);
        assert!(PackedPanel::decode(&bad_magic).unwrap_err().contains("magic"));
        // Future version.
        let mut v2 = good.clone();
        v2[8] = 2;
        let sum = fnv1a64(&v2[..v2.len() - 8]).to_le_bytes();
        let n = v2.len();
        v2[n - 8..].copy_from_slice(&sum);
        assert!(PackedPanel::decode(&v2).unwrap_err().contains("version"));
        // Non-canonical padding bits.
        let mut pad = good.clone();
        let bits_start = HEADER_BYTES + 11 * 8;
        pad[bits_start + 1] |= 0x80; // 11 % 8 = 3 → bits 3..8 of byte 1 are padding
        let sum = fnv1a64(&pad[..pad.len() - 8]).to_le_bytes();
        let n = pad.len();
        pad[n - 8..].copy_from_slice(&sum);
        assert!(PackedPanel::decode(&pad).unwrap_err().contains("padding"));
        // Arbitrary garbage.
        assert!(PackedPanel::decode(b"POETSPNLgarbage").is_err());
        assert!(PackedPanel::decode(&[]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let packed = PackedPanel::from_panel(&panel(4, 13, 5));
        let path = std::env::temp_dir().join(format!(
            "poets-ppnl-test-{}.ppnl",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        packed.write(&path).unwrap();
        assert_eq!(PackedPanel::peek_shape(&path).unwrap(), (4, 13));
        let back = PackedPanel::read(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back, packed);
        assert!(PackedPanel::read("/nonexistent/x.ppnl").unwrap_err().contains("cannot read"));
        assert!(PackedPanel::peek_shape("/nonexistent/x.ppnl").unwrap_err().contains("cannot read"));
    }
}
