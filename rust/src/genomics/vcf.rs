//! A zero-dependency parser for the VCF subset imputation panels use.
//!
//! Reference panels for imputation are phased, bi-allelic SNP matrices — the
//! general VCF zoo (multi-allelic records, indels, unphased or missing
//! genotypes, per-sample annotations) has no meaning to the Li & Stephens
//! state space, so this parser accepts exactly the subset the model consumes
//! and rejects everything else with a `line N:` error.  Strictness is the
//! point: a silently skipped record would shift every downstream marker
//! index and corrupt dosages without any visible failure.
//!
//! Accepted grammar per data line (tab-separated, one chromosome per file):
//!
//! ```text
//! CHROM  POS  ID  REF  ALT  QUAL  FILTER  INFO  FORMAT  sample1 ... sampleS
//! ```
//!
//! * `POS` strictly increasing; `REF`/`ALT` single bases (bi-allelic SNP);
//! * `FORMAT` must contain `GT`; each sample's GT field must be a phased
//!   diploid `a|b` with `a, b ∈ {0, 1}` — so each sample contributes two
//!   haplotype rows and the panel has `2 x S` haplotypes;
//! * genetic distances are derived from physical positions at a constant
//!   rate ([`VcfOptions::morgans_per_bp`], default 1 cM/Mb = 1e-8 M/bp) —
//!   the classic flat-map approximation; a genuine genetic map can replace
//!   it later without touching the parser.

use crate::model::panel::ReferencePanel;

/// Per-site metadata carried alongside the allele matrix (the panel itself
/// only knows alleles + genetic distances).
#[derive(Clone, Debug, PartialEq)]
pub struct Site {
    /// Chromosome name, identical for every site in a panel.
    pub chrom: String,
    /// 1-based physical position (strictly increasing).
    pub pos: u64,
    /// The VCF ID column (`.` when absent — kept verbatim).
    pub id: String,
    /// ALT (allele 1) frequency over the panel haplotypes.
    pub af: f64,
}

/// Parser knobs.
#[derive(Clone, Copy, Debug)]
pub struct VcfOptions {
    /// Physical→genetic conversion rate (Morgans per base pair).  The
    /// default is the field-standard flat 1 cM/Mb.
    pub morgans_per_bp: f64,
}

impl Default for VcfOptions {
    fn default() -> Self {
        VcfOptions {
            morgans_per_bp: 1e-8,
        }
    }
}

/// A parsed panel: the Li & Stephens state space plus site metadata.
#[derive(Clone, Debug)]
pub struct VcfPanel {
    pub panel: ReferencePanel,
    /// One entry per marker column, in panel order.
    pub sites: Vec<Site>,
}

impl VcfPanel {
    /// Number of samples the file carried (haplotypes / 2).
    pub fn n_samples(&self) -> usize {
        self.panel.n_hap() / 2
    }
}

/// Read and parse a VCF file.
pub fn load(path: &str) -> Result<VcfPanel, String> {
    load_with(path, &VcfOptions::default())
}

/// Read and parse a VCF file with explicit options.  Streams line by line
/// (the grammar is strictly line-oriented), so peak memory is the parsed
/// records, not an extra whole-file text copy — chromosome-scale inputs are
/// this path's whole point.
pub fn load_with(path: &str, opts: &VcfOptions) -> Result<VcfPanel, String> {
    use std::io::BufRead;
    let file = std::fs::File::open(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    let lines = std::io::BufReader::new(file)
        .lines()
        .map(|l| l.map_err(|e| format!("read error: {e}")));
    parse_lines(lines, opts).map_err(|e| format!("{path}: {e}"))
}

/// Parse VCF text with default options.
pub fn parse(text: &str) -> Result<VcfPanel, String> {
    parse_with(text, &VcfOptions::default())
}

/// One parsed data line, before column-major assembly.
struct Record {
    site: Site,
    /// `2 x S` alleles for this site: sample s contributes haplotypes
    /// `2s` and `2s + 1`.
    alleles: Vec<u8>,
}

/// Parse VCF text.  Every rejection names the offending 1-based line.
pub fn parse_with(text: &str, opts: &VcfOptions) -> Result<VcfPanel, String> {
    parse_lines(text.lines().map(|l| Ok(l.to_string())), opts)
}

/// Parse a stream of lines (the engine behind [`parse_with`] and the
/// streaming [`load_with`]).
fn parse_lines<I>(lines: I, opts: &VcfOptions) -> Result<VcfPanel, String>
where
    I: Iterator<Item = Result<String, String>>,
{
    if !(opts.morgans_per_bp > 0.0 && opts.morgans_per_bp.is_finite()) {
        return Err("morgans_per_bp must be positive and finite".into());
    }
    let mut header: Option<Vec<String>> = None;
    let mut records: Vec<Record> = Vec::new();
    for (idx, raw) in lines.enumerate() {
        let line_no = idx + 1;
        let fail = |msg: String| format!("line {line_no}: {msg}");
        let raw = raw.map_err(fail)?;
        let line = raw.trim_end_matches('\r');
        if line.is_empty() || line.starts_with("##") {
            continue; // meta-information lines carry nothing the model needs
        }
        if let Some(hdr) = line.strip_prefix('#') {
            if header.is_some() {
                return Err(fail("duplicate #CHROM header line".into()));
            }
            header = Some(parse_header(hdr).map_err(fail)?);
            continue;
        }
        let Some(columns) = &header else {
            return Err(fail("data line before the #CHROM header".into()));
        };
        let record = parse_record(line, columns, records.last()).map_err(fail)?;
        records.push(record);
    }
    if header.is_none() {
        return Err("no #CHROM header line".into());
    }
    if records.len() < 2 {
        return Err(format!(
            "need at least 2 bi-allelic sites, found {}",
            records.len()
        ));
    }

    // Column-major records → row-major panel alleles + flat-map distances.
    let n_mark = records.len();
    let n_hap = records[0].alleles.len();
    let mut alleles = vec![0u8; n_hap * n_mark];
    let mut gen_dist = Vec::with_capacity(n_mark);
    let mut sites = Vec::with_capacity(n_mark);
    for (m, rec) in records.iter().enumerate() {
        for (h, &a) in rec.alleles.iter().enumerate() {
            alleles[h * n_mark + m] = a;
        }
        gen_dist.push(if m == 0 {
            0.0
        } else {
            (rec.site.pos - records[m - 1].site.pos) as f64 * opts.morgans_per_bp
        });
        sites.push(rec.site.clone());
    }
    Ok(VcfPanel {
        panel: ReferencePanel::new(n_hap, n_mark, alleles, gen_dist),
        sites,
    })
}

/// The 8 fixed VCF columns before FORMAT.
const FIXED_COLUMNS: [&str; 8] = [
    "CHROM", "POS", "ID", "REF", "ALT", "QUAL", "FILTER", "INFO",
];

/// Validate the `#CHROM ...` header and return its column names.
fn parse_header(hdr: &str) -> Result<Vec<String>, String> {
    let cols: Vec<String> = hdr.split('\t').map(|c| c.to_string()).collect();
    for (i, want) in FIXED_COLUMNS.iter().enumerate() {
        if cols.get(i).map(String::as_str) != Some(*want) {
            return Err(format!(
                "header column {} must be {want:?}, found {:?}",
                i + 1,
                cols.get(i).map(String::as_str).unwrap_or("<missing>")
            ));
        }
    }
    if cols.get(8).map(String::as_str) != Some("FORMAT") {
        return Err("header needs a FORMAT column (genotype panels carry GT data)".into());
    }
    if cols.len() < 10 {
        return Err("header lists no samples after FORMAT".into());
    }
    Ok(cols)
}

/// Parse one data line against the header; `prev` enforces file-wide
/// invariants (single chromosome, strictly increasing POS, fixed sample
/// count).
fn parse_record(
    line: &str,
    columns: &[String],
    prev: Option<&Record>,
) -> Result<Record, String> {
    let fields: Vec<&str> = line.split('\t').collect();
    if fields.len() != columns.len() {
        return Err(format!(
            "expected {} tab-separated fields (per the header), found {}",
            columns.len(),
            fields.len()
        ));
    }
    // Downstream formats (the .ppnl site records) store these as
    // u16-length strings; anything near that size is not a plausible
    // CHROM/ID anyway, so reject at ingest.
    for (name, value) in [("CHROM", fields[0]), ("ID", fields[2])] {
        if value.len() > u16::MAX as usize {
            return Err(format!(
                "{name} is {} bytes long (limit 65535)",
                value.len()
            ));
        }
    }
    let chrom = fields[0].to_string();
    let pos: u64 = fields[1]
        .parse()
        .map_err(|_| format!("POS {:?} is not a positive integer", fields[1]))?;
    if let Some(p) = prev {
        if chrom != p.site.chrom {
            return Err(format!(
                "chromosome changes from {:?} to {chrom:?} (one chromosome per panel; \
                 split multi-chromosome VCFs before ingest)",
                p.site.chrom
            ));
        }
        if pos <= p.site.pos {
            return Err(format!(
                "POS {pos} is not strictly greater than the previous site's {}",
                p.site.pos
            ));
        }
    }
    let (reference, alt) = (fields[3], fields[4]);
    for (name, allele) in [("REF", reference), ("ALT", alt)] {
        if !matches!(allele, "A" | "C" | "G" | "T") {
            return Err(format!(
                "{name} {allele:?} is not a single base (bi-allelic SNPs only; \
                 multi-allelic and indel records must be filtered before ingest)"
            ));
        }
    }
    if reference == alt {
        return Err(format!("REF and ALT are both {reference:?}"));
    }

    // GT may sit anywhere in FORMAT; everything else in it is ignored.
    let gt_index = fields[8]
        .split(':')
        .position(|k| k == "GT")
        .ok_or_else(|| format!("FORMAT {:?} has no GT field", fields[8]))?;

    let mut alleles = Vec::with_capacity((fields.len() - 9) * 2);
    for (s, sample) in fields[9..].iter().enumerate() {
        let gt = sample.split(':').nth(gt_index).ok_or_else(|| {
            format!("sample {} has no field {gt_index} for GT", s + 1)
        })?;
        let (a, b) = gt.split_once('|').ok_or_else(|| {
            format!(
                "sample {} GT {gt:?} is not phased (expected a|b; unphased '/' and \
                 haploid calls are not representable as reference haplotypes)",
                s + 1
            )
        })?;
        for part in [a, b] {
            alleles.push(match part {
                "0" => 0,
                "1" => 1,
                _ => {
                    return Err(format!(
                        "sample {} GT {gt:?}: allele {part:?} is not 0 or 1 \
                         (missing or multi-allelic genotypes are rejected)",
                        s + 1
                    ));
                }
            });
        }
    }
    if let Some(p) = prev {
        if alleles.len() != p.alleles.len() {
            // Unreachable while the field count is checked against the
            // header, but kept as a defence against future header handling.
            return Err(format!(
                "sample count changed: {} haplotypes here vs {} before",
                alleles.len(),
                p.alleles.len()
            ));
        }
    }
    let af = alleles.iter().map(|&a| a as usize).sum::<usize>() as f64
        / alleles.len() as f64;
    Ok(Record {
        site: Site {
            chrom,
            pos,
            id: fields[2].to_string(),
            af,
        },
        alleles,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const HEADER: &str =
        "#CHROM\tPOS\tID\tREF\tALT\tQUAL\tFILTER\tINFO\tFORMAT\ts1\ts2";

    fn vcf(lines: &[&str]) -> String {
        let mut text = String::from("##fileformat=VCFv4.2\n##source=test\n");
        text.push_str(HEADER);
        text.push('\n');
        for l in lines {
            text.push_str(l);
            text.push('\n');
        }
        text
    }

    fn site(pos: u64, id: &str, gts: [&str; 2]) -> String {
        format!("20\t{pos}\t{id}\tA\tG\t.\tPASS\t.\tGT\t{}\t{}", gts[0], gts[1])
    }

    #[test]
    fn parses_panel_sites_and_distances() {
        let text = vcf(&[
            &site(100, "rs1", ["0|1", "0|0"]),
            &site(300, "rs2", ["1|1", "0|1"]),
            &site(1300, ".", ["0|0", "1|0"]),
        ]);
        let v = parse(&text).unwrap();
        assert_eq!(v.panel.n_hap(), 4);
        assert_eq!(v.panel.n_mark(), 3);
        assert_eq!(v.n_samples(), 2);
        // Haplotype rows: s1 gives rows 0/1, s2 rows 2/3, in GT order.
        assert_eq!(v.panel.haplotype(0), &[0, 1, 0]);
        assert_eq!(v.panel.haplotype(1), &[1, 1, 0]);
        assert_eq!(v.panel.haplotype(2), &[0, 0, 1]);
        assert_eq!(v.panel.haplotype(3), &[0, 1, 0]);
        // Flat-map distances at the default 1e-8 M/bp.
        assert_eq!(v.panel.gen_dist(0), 0.0);
        assert!((v.panel.gen_dist(1) - 200.0 * 1e-8).abs() < 1e-18);
        assert!((v.panel.gen_dist(2) - 1000.0 * 1e-8).abs() < 1e-18);
        // Site metadata, including AF.
        assert_eq!(v.sites[0].chrom, "20");
        assert_eq!(v.sites[0].pos, 100);
        assert_eq!(v.sites[0].id, "rs1");
        assert_eq!(v.sites[2].id, ".");
        assert!((v.sites[0].af - 0.25).abs() < 1e-12);
        assert!((v.sites[1].af - 0.75).abs() < 1e-12);
    }

    #[test]
    fn gt_position_in_format_is_respected() {
        let text = vcf(&[
            "20\t10\t.\tA\tG\t.\tPASS\t.\tDP:GT\t9:0|1\t7:1|0",
            "20\t20\t.\tC\tT\t.\tPASS\t.\tDP:GT\t3:0|0\t2:1|1",
        ]);
        let v = parse(&text).unwrap();
        assert_eq!(v.panel.haplotype(0), &[0, 0]);
        assert_eq!(v.panel.haplotype(3), &[0, 1]);
    }

    #[test]
    fn custom_rate_scales_distances() {
        let text = vcf(&[
            &site(100, ".", ["0|1", "0|0"]),
            &site(200, ".", ["1|0", "0|1"]),
        ]);
        let v = parse_with(&text, &VcfOptions { morgans_per_bp: 1e-6 }).unwrap();
        assert!((v.panel.gen_dist(1) - 1e-4).abs() < 1e-15);
        assert!(parse_with(&text, &VcfOptions { morgans_per_bp: 0.0 }).is_err());
    }

    /// Every rejection must carry the 1-based line number.
    fn err_of(lines: &[&str]) -> String {
        parse(&vcf(lines)).unwrap_err()
    }

    #[test]
    fn rejects_malformed_lines_with_line_numbers() {
        // Data lines start at line 4 (two ## lines + header).
        let base = site(100, ".", ["0|1", "0|0"]);
        for (bad, needle) in [
            (site(100, ".", ["0|1", "0|0"]), "strictly greater"),
            (site(50, ".", ["0|1", "0|0"]), "strictly greater"),
            ("20\tx\t.\tA\tG\t.\tPASS\t.\tGT\t0|1\t0|0".to_string(), "POS"),
            ("20\t200\t.\tA\tG,T\t.\tPASS\t.\tGT\t0|1\t0|0".to_string(), "single base"),
            ("20\t200\t.\tAT\tG\t.\tPASS\t.\tGT\t0|1\t0|0".to_string(), "single base"),
            ("20\t200\t.\tA\tA\t.\tPASS\t.\tGT\t0|1\t0|0".to_string(), "REF and ALT"),
            ("20\t200\t.\tA\tG\t.\tPASS\t.\tGT\t0/1\t0|0".to_string(), "not phased"),
            ("20\t200\t.\tA\tG\t.\tPASS\t.\tGT\t.|1\t0|0".to_string(), "not 0 or 1"),
            ("20\t200\t.\tA\tG\t.\tPASS\t.\tGT\t0|2\t0|0".to_string(), "not 0 or 1"),
            ("20\t200\t.\tA\tG\t.\tPASS\t.\tDP\t9\t7".to_string(), "no GT"),
            ("20\t200\t.\tA\tG\t.\tPASS\t.\tGT\t0|1".to_string(), "fields"),
            ("21\t200\t.\tA\tG\t.\tPASS\t.\tGT\t0|1\t0|0".to_string(), "chromosome"),
            // IDs wider than the .ppnl u16 length field are rejected at
            // ingest, never truncated downstream.
            (
                format!(
                    "20\t200\t{}\tA\tG\t.\tPASS\t.\tGT\t0|1\t0|0",
                    "x".repeat(70_000)
                ),
                "65535",
            ),
        ] {
            let e = err_of(&[base.as_str(), bad.as_str()]);
            assert!(e.contains("line 5"), "{bad:?}: {e}");
            assert!(e.contains(needle), "{bad:?}: expected {needle:?} in {e}");
        }
    }

    #[test]
    fn rejects_structural_problems() {
        assert!(parse("").unwrap_err().contains("no #CHROM"));
        assert!(
            parse("20\t1\t.\tA\tG\t.\t.\t.\tGT\t0|1\n")
                .unwrap_err()
                .contains("before the #CHROM")
        );
        let no_samples = "#CHROM\tPOS\tID\tREF\tALT\tQUAL\tFILTER\tINFO\tFORMAT\n";
        assert!(parse(no_samples).unwrap_err().contains("no samples"));
        let bad_col = "#CHROM\tPOS\tID\tREF\tALT\tQUAL\tFILTER\tEXTRA\tFORMAT\ts1\n";
        assert!(parse(bad_col).unwrap_err().contains("INFO"));
        // A single site cannot form a panel.
        let only = site(100, ".", ["0|1", "0|0"]);
        let e = err_of(&[only.as_str()]);
        assert!(e.contains("at least 2"), "{e}");
        // Duplicate header.
        let two_headers = format!("{HEADER}\n{HEADER}\n");
        assert!(parse(&two_headers).unwrap_err().contains("duplicate"));
    }

    #[test]
    fn load_reports_missing_files() {
        let e = load("/nonexistent/panel.vcf").unwrap_err();
        assert!(e.contains("cannot read"), "{e}");
    }
}
