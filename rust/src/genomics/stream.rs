//! Chromosome-scale streaming: drain a [`WindowPlan`] through the engine
//! with a bounded working set.
//!
//! [`run_windowed_threads`](super::window::run_windowed_threads)
//! materialises every window workload before stitching; fine for dozens of
//! windows, but a chromosome-scale panel sliced into hundreds of windows
//! would hold every slice (panel columns + target observations) in memory
//! at once.  [`run_streamed`] replaces that with a two-stage pipeline:
//!
//! * a **builder thread** slices the next window's [`Workload`] (panel
//!   column selection + target observation slicing — the expensive
//!   allocation) while the engine drains its predecessor;
//! * the **engine stage** receives slices over a rendezvous channel
//!   (`sync_channel(0)`) and runs them in plan order.
//!
//! The rendezvous send is the backpressure: the builder cannot run ahead,
//! so at most **two** window workloads are resident at any instant — the
//! one in the engine and the one prefetched behind it — whatever the plan
//! length, and only one application graph exists at a time.  The report's
//! [`StreamTelemetry`](crate::session::StreamTelemetry) records the
//! measured peak so callers (and the CI smoke test) can assert the bound
//! instead of trusting it.
//!
//! Determinism: windows are received and run in plan order and the stitch +
//! merge is the same code path as the windowed runner
//! (`window::stitch_reports`), so a streamed run
//! is **bit-identical** to `run_windowed_threads` at every host thread
//! count — and to the unwindowed session on a single-window plan
//! (asserted in `tests/parallel_equivalence.rs` / `real_panel_e2e.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::session::{EngineSpec, ImputeReport, ImputeSession, StreamTelemetry, Workload};

use super::window::{WindowPlan, stitch_reports, validate_windowed};

/// Stream a workload through `plan` window by window on `spec`: slice on a
/// builder thread, impute on the caller's thread, stitch one report.
///
/// `configure` applies the per-window session knobs, exactly as in
/// [`run_windowed_threads`](super::window::run_windowed_threads) (the
/// engine selection is applied after it, so `spec` is authoritative); it is
/// called from the consumer side only.  The merged report is bit-identical
/// to the windowed runner's and additionally carries
/// [`StreamTelemetry`](crate::session::StreamTelemetry) with the measured
/// peak number of resident window workloads (≤ 2 by construction).
pub fn run_streamed<F>(
    full: &Workload,
    plan: &WindowPlan,
    spec: EngineSpec,
    configure: F,
) -> Result<ImputeReport, String>
where
    F: Fn(ImputeSession) -> ImputeSession + Sync,
{
    validate_windowed(full, plan, spec)?;

    let n = plan.len();
    let resident = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let reports = std::thread::scope(|sc| -> Result<Vec<ImputeReport>, String> {
        // Rendezvous channel: the builder blocks in `send` until the engine
        // stage takes the slice, so it prefetches exactly one window ahead.
        let (tx, rx) = mpsc::sync_channel::<(usize, Workload)>(0);
        let (residentr, peakr) = (&resident, &peak);
        sc.spawn(move || {
            for (i, win) in plan.windows().iter().enumerate() {
                let sub = plan.slice_workload(full, win);
                let now = residentr.fetch_add(1, Ordering::SeqCst) + 1;
                peakr.fetch_max(now, Ordering::SeqCst);
                if tx.send((i, sub)).is_err() {
                    // The engine stage bailed on an error and dropped the
                    // receiver — stop slicing.
                    break;
                }
            }
        });
        let mut reports: Vec<ImputeReport> = Vec::with_capacity(n);
        for (i, sub) in rx {
            let win = &plan.windows()[i];
            let report = configure(ImputeSession::new(sub))
                .engine(spec)
                .run()
                .map_err(|e| format!("window {i} ([{}, {})): {e}", win.start, win.end))?;
            resident.fetch_sub(1, Ordering::SeqCst);
            reports.push(report);
        }
        Ok(reports)
    })?;

    let mut merged = stitch_reports(full, plan, reports)?;
    merged.stream = Some(StreamTelemetry {
        peak_resident_windows: peak.load(Ordering::SeqCst),
        windows_streamed: n,
    });
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genomics::window::{run_windowed, run_windowed_threads};
    use crate::session::EngineSpec;
    use crate::workload::panelgen::PanelConfig;

    fn workload(n_mark: usize, n_targets: usize) -> Workload {
        Workload::synthetic(
            &PanelConfig {
                n_hap: 8,
                n_mark,
                maf: 0.2,
                annot_ratio: 0.25,
                seed: 77,
                ..PanelConfig::default()
            },
            n_targets,
        )
    }

    #[test]
    fn streamed_matches_windowed_bit_for_bit() {
        let wl = workload(40, 2);
        let plan = WindowPlan::new(40, 26, 19).unwrap();
        let cfg = |s: ImputeSession| s.boards(1).states_per_thread(8);
        let streamed = run_streamed(&wl, &plan, EngineSpec::Event, cfg).unwrap();
        let windowed = run_windowed_threads(&wl, &plan, EngineSpec::Event, 2, cfg).unwrap();
        assert_eq!(streamed.dosages, windowed.dosages, "streaming changed numerics");
        assert_eq!(streamed.windows, windowed.windows);
        let (sm, wm) = (
            streamed.metrics.clone().unwrap(),
            windowed.metrics.clone().unwrap(),
        );
        assert_eq!(sm.sends, wm.sends);
        assert_eq!(sm.sim_cycles, wm.sim_cycles);
        assert_eq!(sm.step_durations, wm.step_durations, "merge order must be plan order");
        // The bounded-memory claim, measured not assumed.
        let t = streamed.stream.expect("streamed runs carry telemetry");
        assert_eq!(t.windows_streamed, plan.len());
        assert!(
            t.peak_resident_windows <= 2,
            "peak resident windows {} exceeds the double-buffer bound",
            t.peak_resident_windows
        );
        assert!(windowed.stream.is_none(), "materialised runs carry none");
    }

    #[test]
    fn single_window_stream_matches_plain_session() {
        let wl = workload(21, 2);
        let plan = WindowPlan::new(21, 64, 4).unwrap();
        let streamed = run_streamed(&wl, &plan, EngineSpec::Event, |s| {
            s.boards(1).states_per_thread(8)
        })
        .unwrap();
        let plain = ImputeSession::new(wl.clone())
            .engine(EngineSpec::Event)
            .boards(1)
            .states_per_thread(8)
            .run()
            .unwrap();
        assert_eq!(streamed.dosages, plain.dosages);
        assert_eq!(streamed.stream.unwrap().peak_resident_windows, 1);
    }

    #[test]
    fn streamed_validation_mirrors_windowed() {
        let wl = workload(30, 1);
        let bad_plan = WindowPlan::new(40, 20, 10).unwrap();
        let streamed = run_streamed(&wl, &bad_plan, EngineSpec::Baseline, |s| s);
        let windowed = run_windowed(&wl, &bad_plan, EngineSpec::Baseline, |s| s);
        assert_eq!(streamed.unwrap_err(), windowed.unwrap_err());
    }

    #[test]
    fn window_errors_stop_the_stream() {
        // A per-window failure must surface as that window's error, not a
        // hang (the builder thread unblocks when the receiver drops).
        let wl = workload(40, 2);
        let plan = WindowPlan::new(40, 10, 0).unwrap();
        let err = run_streamed(&wl, &plan, EngineSpec::Event, |s| s.batch(0)).unwrap_err();
        assert!(err.contains("window 0"), "{err}");
        assert!(err.contains("batch size 0"), "{err}");
    }
}
