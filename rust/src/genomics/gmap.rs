//! Genetic-map parsing and position→centimorgan interpolation.
//!
//! The VCF parser's flat 1 cM/Mb conversion ([`super::vcf::VcfOptions`]) is
//! the field-standard fallback, but real recombination is wildly non-uniform
//! — hotspots concentrate most crossover events into kilobase-scale
//! intervals.  Since the Li & Stephens transition probabilities are driven
//! by *genetic* distance, a genuine map materially changes imputation
//! around hotspots.  `panel ingest --genetic-map PATH` replaces the flat
//! conversion with this module's piecewise-linear interpolation.
//!
//! Two common published formats are auto-detected by column count
//! (whitespace-separated; a single leading non-numeric header line is
//! skipped, as are `#` comments):
//!
//! * **PLINK** (4 columns): `chrom  id  cM  bp` — the `.map`-style layout
//!   used by PLINK and shapeit/beagle map distributions;
//! * **HapMap** (3 columns): `bp  rate(cM/Mb)  cM` — the classic HapMap
//!   `genetic_map_chr*.txt` layout (the rate column is ignored; the
//!   cumulative map is what interpolation needs).
//!
//! Both reduce to knots `(bp, cumulative cM)`: strictly increasing
//! positions, non-decreasing map values.  [`GeneticMap::cm_at`] linearly
//! interpolates between knots and extrapolates beyond either end with the
//! boundary segment's slope (a panel slightly wider than its map should
//! degrade gracefully, not fail).

use crate::model::panel::ReferencePanel;

use super::vcf::VcfPanel;

/// A cumulative genetic map: knots of (physical bp, cumulative cM).
#[derive(Clone, Debug)]
pub struct GeneticMap {
    positions: Vec<u64>,
    cm: Vec<f64>,
}

impl GeneticMap {
    /// Read and parse a map file.
    pub fn load(path: &str) -> Result<GeneticMap, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
        GeneticMap::parse(&text).map_err(|e| format!("{path}: {e}"))
    }

    /// Parse map text (format auto-detected per the module doc).
    pub fn parse(text: &str) -> Result<GeneticMap, String> {
        let mut positions: Vec<u64> = Vec::new();
        let mut cm: Vec<f64> = Vec::new();
        let mut n_cols: Option<usize> = None;
        let mut chrom: Option<String> = None;
        for (idx, raw) in text.lines().enumerate() {
            let line_no = idx + 1;
            let fail = |msg: String| format!("line {line_no}: {msg}");
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            let (pos_str, cm_str, chr) = match fields.len() {
                4 => (fields[3], fields[2], Some(fields[0])), // PLINK: chr id cM bp
                3 => (fields[0], fields[2], None),            // HapMap: bp rate cM
                n => {
                    return Err(fail(format!(
                        "expected 4 (PLINK: chr id cM bp) or 3 (HapMap: bp rate cM) \
                         columns, found {n}"
                    )));
                }
            };
            if let Some(expected) = n_cols {
                if fields.len() != expected {
                    return Err(fail(format!(
                        "column count changed from {expected} to {} mid-file",
                        fields.len()
                    )));
                }
            }
            let parsed = pos_str
                .parse::<u64>()
                .ok()
                .zip(cm_str.parse::<f64>().ok().filter(|v| v.is_finite()));
            let Some((pos, map_cm)) = parsed else {
                if positions.is_empty() && n_cols.is_none() {
                    continue; // the one allowed header line
                }
                return Err(fail(format!(
                    "cannot parse position {pos_str:?} / map {cm_str:?} as numbers"
                )));
            };
            n_cols = Some(fields.len());
            if let Some(c) = chr {
                match &chrom {
                    None => chrom = Some(c.to_string()),
                    Some(first) if first != c => {
                        return Err(fail(format!(
                            "chromosome changes from {first:?} to {c:?} \
                             (one chromosome per map; split multi-chromosome maps first)"
                        )));
                    }
                    Some(_) => {}
                }
            }
            if let Some(&prev) = positions.last() {
                if pos <= prev {
                    return Err(fail(format!(
                        "position {pos} is not strictly greater than the previous knot's {prev}"
                    )));
                }
            }
            if let Some(&prev_cm) = cm.last() {
                if map_cm < prev_cm {
                    return Err(fail(format!(
                        "map value {map_cm} cM decreases from the previous knot's {prev_cm} cM \
                         (cumulative maps are non-decreasing)"
                    )));
                }
            }
            positions.push(pos);
            cm.push(map_cm);
        }
        if positions.len() < 2 {
            return Err(format!(
                "need at least 2 map knots to interpolate, found {}",
                positions.len()
            ));
        }
        Ok(GeneticMap { positions, cm })
    }

    /// Number of knots.
    pub fn len(&self) -> usize {
        self.positions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.positions.is_empty()
    }

    /// The physical span covered by knots (interpolation range).
    pub fn span(&self) -> (u64, u64) {
        (self.positions[0], *self.positions.last().expect(">= 2 knots"))
    }

    /// Cumulative map value at a physical position: linear interpolation
    /// between bracketing knots, boundary-slope extrapolation outside the
    /// knot span.
    pub fn cm_at(&self, pos: u64) -> f64 {
        let n = self.positions.len();
        let segment = |i: usize| {
            // Slope of the segment ending at knot i (positions are strictly
            // increasing, so the denominator is never zero).
            (self.cm[i] - self.cm[i - 1])
                / (self.positions[i] - self.positions[i - 1]) as f64
        };
        match self.positions.binary_search(&pos) {
            Ok(i) => self.cm[i],
            Err(0) => self.cm[0] - (self.positions[0] - pos) as f64 * segment(1),
            Err(i) if i == n => {
                self.cm[n - 1] + (pos - self.positions[n - 1]) as f64 * segment(n - 1)
            }
            Err(i) => self.cm[i - 1] + (pos - self.positions[i - 1]) as f64 * segment(i),
        }
    }

    /// Rebuild a parsed panel's genetic distances from this map: marker
    /// `m`'s distance becomes `(cm_at(pos[m]) − cm_at(pos[m−1])) / 100`
    /// Morgans (clamped at 0 against float noise), replacing the flat-rate
    /// distances the VCF parser derived.  Alleles and site metadata are
    /// unchanged.
    pub fn apply(&self, v: &VcfPanel) -> VcfPanel {
        let (n_hap, n_mark) = (v.panel.n_hap(), v.panel.n_mark());
        let mut alleles = Vec::with_capacity(n_hap * n_mark);
        for h in 0..n_hap {
            alleles.extend_from_slice(v.panel.haplotype(h));
        }
        let mut gen_dist = Vec::with_capacity(n_mark);
        let mut prev_cm = 0.0;
        for (m, site) in v.sites.iter().enumerate() {
            let here = self.cm_at(site.pos);
            gen_dist.push(if m == 0 {
                0.0
            } else {
                ((here - prev_cm) / 100.0).max(0.0)
            });
            prev_cm = here;
        }
        VcfPanel {
            panel: ReferencePanel::new(n_hap, n_mark, alleles, gen_dist),
            sites: v.sites.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genomics::vcf;

    const PLINK: &str = "\
20 rs1 0.0 1000
20 rs2 0.1 2000
20 .   2.1 3000
20 rs4 2.2 5000
";

    // The same knots in HapMap layout (rate column is ignored).
    const HAPMAP: &str = "\
position COMBINED_rate(cM/Mb) Genetic_Map(cM)
1000 100.0 0.0
2000 2000.0 0.1
3000 0.05 2.1
5000 0.0 2.2
";

    #[test]
    fn plink_and_hapmap_layouts_parse_to_the_same_map() {
        let a = GeneticMap::parse(PLINK).unwrap();
        let b = GeneticMap::parse(HAPMAP).unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 4);
        assert_eq!(a.span(), (1000, 5000));
        for pos in [500, 1000, 1500, 2500, 3000, 4000, 5000, 6000] {
            assert!(
                (a.cm_at(pos) - b.cm_at(pos)).abs() < 1e-12,
                "pos {pos}: {} vs {}",
                a.cm_at(pos),
                b.cm_at(pos)
            );
        }
    }

    #[test]
    fn interpolation_is_piecewise_linear_with_boundary_extrapolation() {
        let m = GeneticMap::parse(PLINK).unwrap();
        // Exact knots.
        assert_eq!(m.cm_at(1000), 0.0);
        assert!((m.cm_at(3000) - 2.1).abs() < 1e-12);
        // Midpoints: the 2000..3000 hotspot segment rises 2 cM over 1 kb.
        assert!((m.cm_at(2500) - 1.1).abs() < 1e-12);
        assert!((m.cm_at(4000) - 2.15).abs() < 1e-12);
        // Extrapolation uses the boundary segment's slope: head slope is
        // 0.1 cM / 1000 bp, tail slope 0.1 cM / 2000 bp.
        assert!((m.cm_at(500) - -0.05).abs() < 1e-12);
        assert!((m.cm_at(6000) - 2.25).abs() < 1e-12);
    }

    #[test]
    fn comments_blank_lines_and_one_header_are_tolerated() {
        let text = format!("# generated\n\n{PLINK}");
        assert_eq!(GeneticMap::parse(&text).unwrap().len(), 4);
        // HapMap's classic header is not numeric and is skipped once.
        assert_eq!(GeneticMap::parse(HAPMAP).unwrap().len(), 4);
    }

    #[test]
    fn malformed_maps_are_rejected_with_line_numbers() {
        for (text, needle) in [
            ("", "at least 2"),
            ("20 rs1 0.0 1000\n", "at least 2"),
            // Position must strictly increase.
            ("20 a 0.0 1000\n20 b 0.1 1000\n", "strictly greater"),
            ("20 a 0.0 2000\n20 b 0.1 1000\n", "strictly greater"),
            // Cumulative map must not decrease.
            ("20 a 0.5 1000\n20 b 0.1 2000\n", "decreases"),
            // Wrong shape.
            ("20 1000\n", "columns"),
            ("20 a 0.0 1000\n20 b 0.1 2000 extra\n", "columns"),
            ("1000 1.0 0.0\n20 b 0.1 2000\n", "column count changed"),
            // Garbage after the first data row is an error, not a header.
            ("20 a 0.0 1000\n20 b zap 2000\n", "cannot parse"),
            // One chromosome per map.
            ("20 a 0.0 1000\n21 b 0.1 2000\n", "chromosome changes"),
            // Non-finite map values.
            ("20 a 0.0 1000\n20 b inf 2000\n", "cannot parse"),
        ] {
            let e = GeneticMap::parse(text).expect_err(text);
            assert!(e.contains(needle), "{text:?}: expected {needle:?} in {e}");
        }
    }

    #[test]
    fn apply_rebuilds_distances_and_keeps_alleles() {
        let text = "\
##fileformat=VCFv4.2
#CHROM\tPOS\tID\tREF\tALT\tQUAL\tFILTER\tINFO\tFORMAT\ts1\ts2
20\t1000\trs1\tA\tG\t.\tPASS\t.\tGT\t0|1\t0|0
20\t2000\trs2\tC\tT\t.\tPASS\t.\tGT\t1|1\t0|1
20\t2500\trs3\tG\tA\t.\tPASS\t.\tGT\t0|0\t1|0
";
        let flat = vcf::parse(text).unwrap();
        let map = GeneticMap::parse(PLINK).unwrap();
        let mapped = map.apply(&flat);

        // Alleles and sites are untouched.
        assert_eq!(mapped.panel.n_hap(), 4);
        assert_eq!(mapped.panel.n_mark(), 3);
        for h in 0..4 {
            assert_eq!(mapped.panel.haplotype(h), flat.panel.haplotype(h));
        }
        assert_eq!(mapped.sites, flat.sites);

        // Distances are the map's cM deltas in Morgans, not flat-rate bp.
        assert_eq!(mapped.panel.gen_dist(0), 0.0);
        assert!((mapped.panel.gen_dist(1) - 0.1 / 100.0).abs() < 1e-15);
        // 2000..2500 crosses half the 2 cM hotspot segment.
        assert!((mapped.panel.gen_dist(2) - 1.0 / 100.0).abs() < 1e-15);
        // The flat parse, by contrast, made marker 1's gap twice marker 2's.
        assert!(flat.panel.gen_dist(1) > flat.panel.gen_dist(2));
        // The map inverts that: the hotspot dominates.
        assert!(mapped.panel.gen_dist(2) > mapped.panel.gen_dist(1));
    }
}
