//! Admission queue + request coalescer.
//!
//! A bounded FIFO of submitted requests guarded by one mutex/condvar pair.
//! Workers pop *coalesced groups*: the head request plus every other pending
//! request for the same (panel, engine) key, up to a target budget
//! ([`CoalescePolicy::max_batch_targets`]), optionally lingering
//! ([`CoalescePolicy::max_linger`]) for stragglers so short bursts merge
//! even when the queue momentarily empties.  Coalescing is strictly
//! work-conserving apart from that bounded linger: a group never waits once
//! its target budget is met, and `max_batch_targets = 1` disables merging
//! (and therefore lingering) entirely.
//!
//! Admission control is a hard cap on pending requests
//! ([`CoalescePolicy`] is about *shape*; capacity lives on the service
//! config): a full queue rejects at submit time with an `admission:` error
//! rather than queueing unboundedly — under overload a service must shed
//! load, not grow latency without bound.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::model::panel::TargetHaplotype;
use crate::session::EngineSpec;

use super::report::ServeReport;

/// One tenant request: impute `targets` against the named panel on the
/// selected compute plane.
#[derive(Clone, Debug)]
pub struct ImputeRequest {
    /// Registry name ([`crate::serve::PanelRegistry`]); requests with the
    /// same name share one in-memory panel.
    pub panel: String,
    /// Compute plane to run.
    pub engine: EngineSpec,
    /// Target haplotypes to impute — explicit observation vectors, or a
    /// deferred server-side mint executed in the worker pool.
    pub targets: RequestTargets,
}

/// A request's target payload.
///
/// `Mint` defers server-side target minting (`synth_targets` request lines)
/// to the **worker pool**: the stream-reader thread no longer resolves the
/// panel just to materialise targets, so a slow file-backed panel load can
/// never head-of-line block admission of later request lines.  The declared
/// `count` is what the coalescer's target budget accounts before the mint
/// runs ([`RequestTargets::declared_len`]).
#[derive(Clone, Debug)]
pub enum RequestTargets {
    /// Observation vectors supplied by the client (`-1` = untyped marker).
    Explicit(Vec<TargetHaplotype>),
    /// Mint `count` targets from the panel's recipe (or mosaic fallback) in
    /// the worker, seeded by `seed` — see `RegisteredPanel::minted_targets`.
    Mint { count: usize, seed: u64 },
}

impl RequestTargets {
    /// Target count as declared at admission time: the explicit vector's
    /// length, or the mint width.  This is what admission checks and what
    /// the coalescer's `max_batch_targets` budget charges.
    pub fn declared_len(&self) -> usize {
        match self {
            RequestTargets::Explicit(ts) => ts.len(),
            RequestTargets::Mint { count, .. } => *count,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.declared_len() == 0
    }
}

impl Default for RequestTargets {
    fn default() -> Self {
        RequestTargets::Explicit(Vec::new())
    }
}

impl From<Vec<TargetHaplotype>> for RequestTargets {
    fn from(targets: Vec<TargetHaplotype>) -> Self {
        RequestTargets::Explicit(targets)
    }
}

/// How the coalescer merges concurrent requests.
#[derive(Clone, Copy, Debug)]
pub struct CoalescePolicy {
    /// Max total targets per coalesced engine batch.  `1` disables
    /// coalescing (every request runs alone).  A single request larger than
    /// the budget is never split — it runs as its own group.
    pub max_batch_targets: usize,
    /// How long a popped group may wait for more same-key requests while
    /// under budget.  Zero means "merge only what is already queued".
    pub max_linger: Duration,
}

impl Default for CoalescePolicy {
    fn default() -> Self {
        CoalescePolicy {
            max_batch_targets: 16,
            max_linger: Duration::from_millis(2),
        }
    }
}

impl CoalescePolicy {
    /// A policy that never merges requests.
    pub fn off() -> CoalescePolicy {
        CoalescePolicy {
            max_batch_targets: 1,
            max_linger: Duration::ZERO,
        }
    }

    pub fn is_off(&self) -> bool {
        self.max_batch_targets <= 1
    }
}

/// A request admitted to the queue, waiting for a worker.
pub(crate) struct Pending {
    pub id: u64,
    pub req: ImputeRequest,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Result<ServeReport, String>>,
}

/// Handle returned by `Service::submit`: redeem it for the request's report.
pub struct Ticket {
    pub(crate) id: u64,
    pub(crate) rx: mpsc::Receiver<Result<ServeReport, String>>,
}

impl Ticket {
    /// The service-assigned request id (matches the report's
    /// `serve.request_id`).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the request is served (or failed).
    pub fn wait(self) -> Result<ServeReport, String> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err("service dropped the request (worker exited)".into()))
    }

    /// Non-blocking poll: `None` while the request is still in flight.  A
    /// dead worker (sender dropped without a reply) yields the same error
    /// as [`Ticket::wait`] rather than `None`, so pollers can't spin on a
    /// request that will never complete.
    pub fn try_wait(&self) -> Option<Result<ServeReport, String>> {
        match self.rx.try_recv() {
            Ok(result) => Some(result),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err("service dropped the request (worker exited)".into()))
            }
        }
    }
}

/// Aggregate service counters (snapshot via `Service::stats`).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    /// Requests admitted to the queue.
    pub accepted: u64,
    /// Requests refused at submit time (queue full / invalid / shutdown).
    pub rejected: u64,
    /// Requests answered successfully.
    pub completed: u64,
    /// Requests answered with an error.
    pub failed: u64,
    /// Coalesced engine batches run.
    pub batches: u64,
    /// Sum of batch widths (requests) over all batches.
    pub coalesced_requests: u64,
    /// Multi-request groups on the event plane whose member targets were
    /// merged into ONE wave sweep (responses scattered back per request).
    pub merged_waves: u64,
}

impl ServiceStats {
    /// Mean requests per coalesced batch (1.0 = coalescing never merged).
    pub fn mean_batch_width(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.coalesced_requests as f64 / self.batches as f64
        }
    }
}

/// Mutex-guarded queue state shared by submitters and workers.
#[derive(Default)]
pub(crate) struct QueueState {
    pub pending: VecDeque<Pending>,
    pub shutdown: bool,
    pub next_batch_id: u64,
    pub stats: ServiceStats,
}

impl QueueState {
    /// Pull every queued request matching `key` into `group`, respecting the
    /// remaining target budget.  Returns the updated total target count.
    pub fn drain_matching(
        &mut self,
        key: (&str, EngineSpec),
        group: &mut Vec<Pending>,
        mut total_targets: usize,
        max_batch_targets: usize,
    ) -> usize {
        let mut i = 0;
        while i < self.pending.len() {
            if total_targets >= max_batch_targets {
                break;
            }
            let p = &self.pending[i];
            let fits = p.req.panel == key.0
                && p.req.engine == key.1
                && total_targets + p.req.targets.declared_len() <= max_batch_targets;
            if fits {
                let p = self.pending.remove(i).expect("index checked above");
                total_targets += p.req.targets.declared_len();
                group.push(p);
            } else {
                i += 1;
            }
        }
        total_targets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(id: u64, panel: &str, spec: EngineSpec, n_targets: usize) -> Pending {
        // These queue-shape tests never redeem tickets, so the receiver side
        // is dropped immediately.
        let (tx, _rx) = mpsc::channel();
        Pending {
            id,
            req: ImputeRequest {
                panel: panel.to_string(),
                engine: spec,
                targets: vec![TargetHaplotype::new(vec![-1, 0, 1]); n_targets].into(),
            },
            enqueued: Instant::now(),
            reply: tx,
        }
    }

    fn pending_mint(id: u64, panel: &str, spec: EngineSpec, count: usize) -> Pending {
        let (tx, _rx) = mpsc::channel();
        Pending {
            id,
            req: ImputeRequest {
                panel: panel.to_string(),
                engine: spec,
                targets: RequestTargets::Mint { count, seed: 0 },
            },
            enqueued: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn drain_matching_respects_key_and_budget() {
        let mut st = QueueState::default();
        st.pending.push_back(pending(1, "a", EngineSpec::Event, 2));
        st.pending.push_back(pending(2, "b", EngineSpec::Event, 1));
        st.pending.push_back(pending(3, "a", EngineSpec::Rank1, 1));
        st.pending.push_back(pending(4, "a", EngineSpec::Event, 3));
        st.pending.push_back(pending(5, "a", EngineSpec::Event, 1));

        let mut group = Vec::new();
        // Budget 4, 1 target already in hand: takes #1 (2), skips #4 (would
        // overflow), takes #5 (1).
        let total = st.drain_matching(("a", EngineSpec::Event), &mut group, 1, 4);
        assert_eq!(total, 4);
        assert_eq!(
            group.iter().map(|p| p.id).collect::<Vec<_>>(),
            vec![1, 5]
        );
        // Non-matching and oversized requests stay queued, order preserved.
        assert_eq!(
            st.pending.iter().map(|p| p.id).collect::<Vec<_>>(),
            vec![2, 3, 4]
        );
    }

    #[test]
    fn coalesce_policy_off_disables_merging() {
        assert!(CoalescePolicy::off().is_off());
        assert!(!CoalescePolicy::default().is_off());
        let mut st = QueueState::default();
        st.pending.push_back(pending(1, "a", EngineSpec::Event, 1));
        let mut group = Vec::new();
        let total = st.drain_matching(("a", EngineSpec::Event), &mut group, 1, 1);
        assert_eq!(total, 1);
        assert!(group.is_empty(), "budget 1 means the head runs alone");
    }

    #[test]
    fn drain_matching_charges_declared_mint_width() {
        // A deferred mint counts its declared width against the budget even
        // though no targets exist yet (they are minted in the worker pool).
        let mut st = QueueState::default();
        st.pending.push_back(pending_mint(1, "a", EngineSpec::Event, 3));
        st.pending.push_back(pending_mint(2, "a", EngineSpec::Event, 3));
        let mut group = Vec::new();
        let total = st.drain_matching(("a", EngineSpec::Event), &mut group, 1, 4);
        assert_eq!(total, 4, "only the first 3-wide mint fits a budget of 4");
        assert_eq!(group.iter().map(|p| p.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(st.pending.len(), 1);
        assert_eq!(RequestTargets::Mint { count: 3, seed: 0 }.declared_len(), 3);
        assert!(RequestTargets::Mint { count: 0, seed: 9 }.is_empty());
    }

    #[test]
    fn stats_mean_width() {
        let mut s = ServiceStats::default();
        assert_eq!(s.mean_batch_width(), 0.0);
        s.batches = 4;
        s.coalesced_requests = 10;
        assert!((s.mean_batch_width() - 2.5).abs() < 1e-12);
    }
}
