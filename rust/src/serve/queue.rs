//! Admission queue + request coalescer + admission-control state.
//!
//! A bounded FIFO of submitted requests guarded by one mutex/condvar pair.
//! Workers pop *coalesced groups*: the head request plus every other pending
//! request for the same (panel, engine) key, up to a target budget
//! ([`CoalescePolicy::max_batch_targets`]), optionally lingering
//! ([`CoalescePolicy::max_linger`]) for stragglers so short bursts merge
//! even when the queue momentarily empties.  Coalescing is strictly
//! work-conserving apart from that bounded linger: a group never waits once
//! its target budget is met, and `max_batch_targets = 1` disables merging
//! (and therefore lingering) entirely.  Streamed requests
//! ([`ImputeRequest::stream`]) are never coalesced — their windowed
//! execution shape is per-request.
//!
//! Admission control is layered, cheapest check first, and every shed is a
//! typed in-band `serve-error/v1` string:
//!
//! * `admission:` — structural refusals: empty request, shutdown, or the
//!   hard cap on pending requests (a full queue rejects at submit time
//!   rather than queueing unboundedly — under overload a service must shed
//!   load, not grow latency without bound).
//! * `quota:` — per-tenant token buckets ([`TenantQuota`]): each request
//!   naming a `tenant` takes one token; an empty bucket sheds before any
//!   work is done.
//! * `deadline:` — requests carrying `deadline_ms` are shed at admission
//!   when the queue-age estimate (pending depth × recent mean service time
//!   ÷ workers, an EWMA maintained by the workers) already exceeds the
//!   deadline, and expired again worker-side after mint/queue time if the
//!   true age overran while waiting.
//!
//! Observability rides the same state: requests opting into `spans` carry a
//! [`RequestSpan`] timeline stamped phase-by-phase as workers serve them,
//! and [`ServiceStats`] aggregates engine-cache hit/miss/eviction counters
//! plus log2-µs queue-wait / service-time histograms
//! ([`crate::obs::latency_bucket`]) surfaced by `serve-stats/v1`.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::model::panel::TargetHaplotype;
use crate::obs::{LATENCY_BUCKETS, latency_bucket};
use crate::session::EngineSpec;

use super::report::ServeReport;

/// One tenant request: impute `targets` against the named panel on the
/// selected compute plane.
#[derive(Clone, Debug)]
pub struct ImputeRequest {
    /// Registry name ([`crate::serve::PanelRegistry`]); requests with the
    /// same name share one in-memory panel.
    pub panel: String,
    /// Compute plane to run.
    pub engine: EngineSpec,
    /// Target haplotypes to impute — explicit observation vectors, or a
    /// deferred server-side mint executed in the worker pool.
    pub targets: RequestTargets,
    /// Optional tenant name for per-tenant token-bucket quotas.  Requests
    /// without a tenant are never quota-shed.
    pub tenant: Option<String>,
    /// Optional latency budget in milliseconds.  Admission sheds with a
    /// `deadline:` error when the queue-age estimate already exceeds it;
    /// the worker re-checks the true age (queue wait + mint time) before
    /// running the engine.
    pub deadline_ms: Option<u64>,
    /// Optional windowed streaming: run the request window-by-window and
    /// emit dosage rows as each window's core span completes.  Streamed
    /// requests never coalesce.
    pub stream: Option<StreamSpec>,
    /// Opt into a per-request span timeline ([`RequestSpan`]) in the
    /// response's `serve.spans` object.  Off by default: span stamps cost a
    /// handful of `Instant::now` reads per request, and responses stay
    /// byte-stable for clients that never asked for timings.
    pub spans: bool,
}

impl ImputeRequest {
    /// A plain request (no tenant, no deadline, no streaming) — the shape
    /// every pre-quota caller used.
    pub fn new(
        panel: impl Into<String>,
        engine: EngineSpec,
        targets: impl Into<RequestTargets>,
    ) -> ImputeRequest {
        ImputeRequest {
            panel: panel.into(),
            engine,
            targets: targets.into(),
            tenant: None,
            deadline_ms: None,
            stream: None,
            spans: false,
        }
    }

    /// Attach a tenant name (subject to the service's [`TenantQuota`]).
    pub fn tenant(mut self, tenant: impl Into<String>) -> Self {
        self.tenant = Some(tenant.into());
        self
    }

    /// Attach a latency budget in milliseconds.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// Request windowed streaming with the given window length / overlap.
    pub fn stream_windows(mut self, window: usize, overlap: usize) -> Self {
        self.stream = Some(StreamSpec { window, overlap });
        self
    }

    /// Opt into the per-request [`RequestSpan`] timeline in the response.
    pub fn with_spans(mut self) -> Self {
        self.spans = true;
        self
    }
}

/// One request's span timeline: microsecond offsets from the submit call's
/// entry instant, one stamp per serve phase, surfaced in the response's
/// `serve.spans` object when the request set `"spans": true`.
///
/// Stamps are monotone by construction — every `mark_*` clamps against the
/// previous phase, and [`RequestSpan::mark_responded`] forward-fills any
/// phase a path skipped (e.g. streamed requests have no group prepare) — so
/// `admitted_us <= dequeued_us <= minted_us <= prepared_us <= run_us <=
/// responded_us` always holds in the emitted document.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestSpan {
    /// Admission checks passed; the request entered the queue.
    pub admitted_us: u64,
    /// A worker popped the request's coalesced group (queue wait ends).
    pub dequeued_us: u64,
    /// Targets materialised (deferred mints run here; explicit sets are
    /// shape-checked — for those this stamp trails `dequeued_us` closely).
    pub minted_us: u64,
    /// Engine built/fetched from the worker cache and bound to the panel.
    pub prepared_us: u64,
    /// Engine run returned (dosages in hand).
    pub run_us: u64,
    /// Reply handed to the ticket channel.
    pub responded_us: u64,
    /// Requests sharing this request's coalesced batch (1 = ran alone).
    pub coalesced_with: u32,
    /// Whether an event-plane group merged this request's targets into one
    /// shared wave sweep.
    pub merged_wave: bool,
}

impl RequestSpan {
    pub fn mark_dequeued(&mut self, us: u64) {
        self.dequeued_us = us.max(self.admitted_us);
    }

    pub fn mark_minted(&mut self, us: u64) {
        self.minted_us = us.max(self.dequeued_us);
    }

    pub fn mark_prepared(&mut self, us: u64) {
        self.prepared_us = us.max(self.minted_us);
    }

    pub fn mark_run(&mut self, us: u64) {
        self.run_us = us.max(self.prepared_us);
    }

    /// Final stamp: forward-fills any phase this request's path never
    /// touched, then records the reply instant.
    pub fn mark_responded(&mut self, us: u64) {
        self.dequeued_us = self.dequeued_us.max(self.admitted_us);
        self.minted_us = self.minted_us.max(self.dequeued_us);
        self.prepared_us = self.prepared_us.max(self.minted_us);
        self.run_us = self.run_us.max(self.prepared_us);
        self.responded_us = us.max(self.run_us);
    }
}

/// Windowed-streaming shape for one request (see
/// [`crate::genomics::window::WindowPlan`] for the chunking semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamSpec {
    /// Markers per window (overlap included).
    pub window: usize,
    /// Markers shared between adjacent windows.
    pub overlap: usize,
}

/// A request's target payload.
///
/// `Mint` defers server-side target minting (`synth_targets` request lines)
/// to the **worker pool**: the stream-reader thread no longer resolves the
/// panel just to materialise targets, so a slow file-backed panel load can
/// never head-of-line block admission of later request lines.  The declared
/// `count` is what the coalescer's target budget accounts before the mint
/// runs ([`RequestTargets::declared_len`]).
#[derive(Clone, Debug)]
pub enum RequestTargets {
    /// Observation vectors supplied by the client (`-1` = untyped marker).
    Explicit(Vec<TargetHaplotype>),
    /// Mint `count` targets from the panel's recipe (or mosaic fallback) in
    /// the worker, seeded by `seed` — see `RegisteredPanel::minted_targets`.
    Mint { count: usize, seed: u64 },
}

impl RequestTargets {
    /// Target count as declared at admission time: the explicit vector's
    /// length, or the mint width.  This is what admission checks and what
    /// the coalescer's `max_batch_targets` budget charges.
    pub fn declared_len(&self) -> usize {
        match self {
            RequestTargets::Explicit(ts) => ts.len(),
            RequestTargets::Mint { count, .. } => *count,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.declared_len() == 0
    }
}

impl Default for RequestTargets {
    fn default() -> Self {
        RequestTargets::Explicit(Vec::new())
    }
}

impl From<Vec<TargetHaplotype>> for RequestTargets {
    fn from(targets: Vec<TargetHaplotype>) -> Self {
        RequestTargets::Explicit(targets)
    }
}

/// How the coalescer merges concurrent requests.
#[derive(Clone, Copy, Debug)]
pub struct CoalescePolicy {
    /// Max total targets per coalesced engine batch.  `1` disables
    /// coalescing (every request runs alone).  A single request larger than
    /// the budget is never split — it runs as its own group.
    pub max_batch_targets: usize,
    /// How long a popped group may wait for more same-key requests while
    /// under budget.  Zero means "merge only what is already queued".
    pub max_linger: Duration,
}

impl Default for CoalescePolicy {
    fn default() -> Self {
        CoalescePolicy {
            max_batch_targets: 16,
            max_linger: Duration::from_millis(2),
        }
    }
}

impl CoalescePolicy {
    /// A policy that never merges requests.
    pub fn off() -> CoalescePolicy {
        CoalescePolicy {
            max_batch_targets: 1,
            max_linger: Duration::ZERO,
        }
    }

    pub fn is_off(&self) -> bool {
        self.max_batch_targets <= 1
    }
}

/// Per-tenant token-bucket quota shared by every tenant name.
///
/// A bucket starts full at `burst` tokens, refills continuously at
/// `rate_per_s`, and each admitted request spends one token.  `rate_per_s =
/// 0` never refills — with `burst = 1` that admits exactly one request per
/// tenant, the deterministic shape the quota tests and CI smoke rely on.
#[derive(Clone, Copy, Debug)]
pub struct TenantQuota {
    /// Tokens added per second (sustained request rate).
    pub rate_per_s: f64,
    /// Bucket capacity (burst allowance).
    pub burst: f64,
}

impl TenantQuota {
    pub fn new(rate_per_s: f64, burst: f64) -> TenantQuota {
        TenantQuota { rate_per_s, burst }
    }
}

/// One tenant's bucket level at its last refill instant.
struct TokenBucket {
    tokens: f64,
    refilled: Instant,
}

/// One streamed window's worth of dosage rows (the window's *core* span —
/// the slice of markers this window owns after overlap trimming).
#[derive(Clone, Debug)]
pub struct ServePart {
    /// Service-assigned request id (matches the final report's
    /// `serve.request_id`).
    pub request_id: u64,
    /// Zero-based window index in plan order.
    pub window_index: usize,
    /// Total windows the request will stream.
    pub n_windows: usize,
    /// First marker (inclusive) of this part's core span.
    pub core_start: usize,
    /// One past the last marker of this part's core span.
    pub core_end: usize,
    /// Per-target dosage rows covering `core_start..core_end`.
    pub rows: Vec<Vec<f32>>,
}

/// A request admitted to the queue, waiting for a worker.
pub(crate) struct Pending {
    pub id: u64,
    pub req: ImputeRequest,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Result<ServeReport, String>>,
    /// Present only for streamed requests: where the worker pushes
    /// [`ServePart`]s as windows complete.  Dropped (with the `Pending`)
    /// after the final reply, which is how the ticket side learns the part
    /// stream ended.
    pub parts: Option<mpsc::Sender<ServePart>>,
    /// Span timeline under construction, present only when the request set
    /// `spans` — workers stamp phases as they pass, `finish` attaches the
    /// closed span to the response.
    pub span: Option<RequestSpan>,
}

impl Pending {
    /// Microseconds since this request entered `Service::submit` — the
    /// origin every [`RequestSpan`] stamp is measured from.
    pub fn age_us(&self) -> u64 {
        self.enqueued.elapsed().as_micros() as u64
    }
}

/// Handle returned by `Service::submit`: redeem it for the request's report.
pub struct Ticket {
    pub(crate) id: u64,
    pub(crate) rx: mpsc::Receiver<Result<ServeReport, String>>,
    pub(crate) parts: Option<mpsc::Receiver<ServePart>>,
}

impl Ticket {
    /// The service-assigned request id (matches the report's
    /// `serve.request_id`).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Whether this ticket streams [`ServePart`]s before its final report.
    pub fn is_streaming(&self) -> bool {
        self.parts.is_some()
    }

    /// Block for the next streamed part.  `None` when the part stream has
    /// ended (the final report is ready or imminent) or the request does
    /// not stream.
    pub fn recv_part(&self) -> Option<ServePart> {
        self.parts.as_ref().and_then(|rx| rx.recv().ok())
    }

    /// Non-blocking part poll: `None` when no part is ready right now.
    pub fn try_recv_part(&self) -> Option<ServePart> {
        self.parts.as_ref().and_then(|rx| rx.try_recv().ok())
    }

    /// Block until the request is served (or failed).  For streamed
    /// requests the final report still carries the complete stitched dosage
    /// matrix, so callers that ignore parts see exactly the non-streamed
    /// result.
    pub fn wait(self) -> Result<ServeReport, String> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err("service dropped the request (worker exited)".into()))
    }

    /// Non-blocking poll: `None` while the request is still in flight.  A
    /// dead worker (sender dropped without a reply) yields the same error
    /// as [`Ticket::wait`] rather than `None`, so pollers can't spin on a
    /// request that will never complete.
    pub fn try_wait(&self) -> Option<Result<ServeReport, String>> {
        match self.rx.try_recv() {
            Ok(result) => Some(result),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err("service dropped the request (worker exited)".into()))
            }
        }
    }
}

/// Aggregate service counters (snapshot via `Service::stats`).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    /// Requests admitted to the queue.
    pub accepted: u64,
    /// Requests refused at submit time (queue full / invalid / shutdown /
    /// quota / deadline).
    pub rejected: u64,
    /// Requests answered successfully.
    pub completed: u64,
    /// Requests answered with an error.
    pub failed: u64,
    /// Coalesced engine batches run.
    pub batches: u64,
    /// Sum of batch widths (requests) over all batches.
    pub coalesced_requests: u64,
    /// Multi-request groups on the event plane whose member targets were
    /// merged into ONE wave sweep (responses scattered back per request).
    pub merged_waves: u64,
    /// Requests shed with a `quota:` error (tenant bucket empty).  A subset
    /// of `rejected`.
    pub shed_quota: u64,
    /// Requests shed with a `deadline:` error — at admission (subset of
    /// `rejected`) or expired worker-side after queue + mint time (subset
    /// of `failed`).
    pub shed_deadline: u64,
    /// Worker engine-cache hits: a popped group found its (panel, engine)
    /// pair already built on its worker.
    pub cache_hits: u64,
    /// Worker engine-cache misses (engine built from scratch).
    pub cache_misses: u64,
    /// Engines evicted from a worker cache at capacity (LRU victim).
    pub cache_evictions: u64,
    /// Requests whose engine run died (panic or error) and were retried
    /// once on a freshly built engine before any in-band failure.
    pub retried: u64,
    /// Completed engine runs whose DES metrics reported fault recovery
    /// (tile deaths remapped + replayed).
    pub recovered_runs: u64,
    /// Simulated recovery cycles summed over those runs.
    pub recovery_cycles: u64,
    /// Whether the service's most recent event-plane run went through fault
    /// recovery — while set, admission stretches deadline estimates by
    /// [`DEGRADED_WAIT_FACTOR`]; the next clean run clears it.  Sharded
    /// aggregates OR this across shards.
    pub degraded: bool,
    /// Queue-wait histogram: log2-µs buckets ([`latency_bucket`]) of
    /// admission → group-pop wait, one count per dequeued request.
    pub queue_wait_hist: [u64; LATENCY_BUCKETS],
    /// Per-request engine service-time histogram, same buckets, fed by the
    /// same observations as the admission EWMA (merged waves contribute
    /// their per-request share).
    pub service_hist: [u64; LATENCY_BUCKETS],
}

impl ServiceStats {
    /// Mean requests per coalesced batch (1.0 = coalescing never merged).
    pub fn mean_batch_width(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.coalesced_requests as f64 / self.batches as f64
        }
    }

    /// Element-wise sum — used to aggregate per-shard stats.
    pub fn merge(&self, other: &ServiceStats) -> ServiceStats {
        let mut queue_wait_hist = self.queue_wait_hist;
        let mut service_hist = self.service_hist;
        for (a, b) in queue_wait_hist.iter_mut().zip(other.queue_wait_hist.iter()) {
            *a += *b;
        }
        for (a, b) in service_hist.iter_mut().zip(other.service_hist.iter()) {
            *a += *b;
        }
        ServiceStats {
            accepted: self.accepted + other.accepted,
            rejected: self.rejected + other.rejected,
            completed: self.completed + other.completed,
            failed: self.failed + other.failed,
            batches: self.batches + other.batches,
            coalesced_requests: self.coalesced_requests + other.coalesced_requests,
            merged_waves: self.merged_waves + other.merged_waves,
            shed_quota: self.shed_quota + other.shed_quota,
            shed_deadline: self.shed_deadline + other.shed_deadline,
            cache_hits: self.cache_hits + other.cache_hits,
            cache_misses: self.cache_misses + other.cache_misses,
            cache_evictions: self.cache_evictions + other.cache_evictions,
            retried: self.retried + other.retried,
            recovered_runs: self.recovered_runs + other.recovered_runs,
            recovery_cycles: self.recovery_cycles + other.recovery_cycles,
            degraded: self.degraded || other.degraded,
            queue_wait_hist,
            service_hist,
        }
    }
}

/// EWMA smoothing factor for the per-request service-time estimate (higher
/// = more reactive to the latest batch).
const SERVICE_EWMA_ALPHA: f64 = 0.3;

/// Deadline-estimate stretch applied while the service is degraded (its
/// last event-plane run went through tile-failure recovery): replayed
/// supersteps and restores make near-term service times pessimistic, so
/// admission sheds tight deadlines earlier instead of accepting requests it
/// will expire worker-side.
pub const DEGRADED_WAIT_FACTOR: f64 = 2.0;

/// Mutex-guarded queue state shared by submitters and workers.
#[derive(Default)]
pub(crate) struct QueueState {
    pub pending: VecDeque<Pending>,
    pub shutdown: bool,
    pub next_batch_id: u64,
    pub stats: ServiceStats,
    /// Per-tenant token buckets (lazily created on first sighting).
    buckets: HashMap<String, TokenBucket>,
    /// EWMA of per-request engine service time (seconds), fed by workers.
    /// Zero until the first completion — admission then has no basis for a
    /// wait estimate and deadline sheds only on a non-empty queue.
    pub ewma_service_seconds: f64,
}

impl QueueState {
    /// Spend one token from `tenant`'s bucket under `quota`; `false` means
    /// the bucket is empty and the request must be quota-shed.
    pub fn take_token(&mut self, tenant: &str, quota: &TenantQuota, now: Instant) -> bool {
        let bucket = self
            .buckets
            .entry(tenant.to_string())
            .or_insert_with(|| TokenBucket {
                tokens: quota.burst,
                refilled: now,
            });
        let elapsed = now.duration_since(bucket.refilled).as_secs_f64();
        bucket.tokens = (bucket.tokens + elapsed * quota.rate_per_s).min(quota.burst);
        bucket.refilled = now;
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Fold one observed per-request service time into the EWMA (and the
    /// `serve-stats/v1` service-time histogram — one edit point covers the
    /// solo, coalesced and merged-wave paths alike).
    pub fn note_service_time(&mut self, seconds: f64) {
        if !seconds.is_finite() || seconds < 0.0 {
            return;
        }
        self.stats.service_hist[latency_bucket((seconds * 1e6) as u64)] += 1;
        if self.ewma_service_seconds == 0.0 {
            self.ewma_service_seconds = seconds;
        } else {
            self.ewma_service_seconds = SERVICE_EWMA_ALPHA * seconds
                + (1.0 - SERVICE_EWMA_ALPHA) * self.ewma_service_seconds;
        }
    }

    /// Queue-age estimate for a request admitted *now*: pending depth ×
    /// recent mean service time ÷ worker count.  Deliberately ignores
    /// in-flight work (optimistic): deadline admission sheds only when even
    /// the optimistic estimate busts the budget.  While the service is
    /// degraded (active fault recovery on its last run) the estimate is
    /// stretched by [`DEGRADED_WAIT_FACTOR`].
    pub fn estimated_wait_seconds(&self, workers: usize) -> f64 {
        let base = self.pending.len() as f64 * self.ewma_service_seconds / workers.max(1) as f64;
        if self.stats.degraded {
            base * DEGRADED_WAIT_FACTOR
        } else {
            base
        }
    }

    /// Fold one completed engine run's fault-recovery telemetry into the
    /// stats and the degraded flag: a run that recovered marks the service
    /// degraded (stretching admission estimates), the next clean event-plane
    /// run clears it.
    pub fn note_recovery(&mut self, recovery_cycles: u64, failed_tiles: u64) {
        let recovering = failed_tiles > 0 || recovery_cycles > 0;
        if recovering {
            self.stats.recovered_runs += 1;
            self.stats.recovery_cycles += recovery_cycles;
        }
        self.stats.degraded = recovering;
    }

    /// Pull every queued request matching `key` into `group`, respecting the
    /// remaining target budget.  Streamed requests never merge.  Returns the
    /// updated total target count.
    pub fn drain_matching(
        &mut self,
        key: (&str, EngineSpec),
        group: &mut Vec<Pending>,
        mut total_targets: usize,
        max_batch_targets: usize,
    ) -> usize {
        let mut i = 0;
        while i < self.pending.len() {
            if total_targets >= max_batch_targets {
                break;
            }
            let p = &self.pending[i];
            let fits = p.req.panel == key.0
                && p.req.engine == key.1
                && p.req.stream.is_none()
                && total_targets + p.req.targets.declared_len() <= max_batch_targets;
            if fits {
                let p = self.pending.remove(i).expect("index checked above");
                total_targets += p.req.targets.declared_len();
                group.push(p);
            } else {
                i += 1;
            }
        }
        total_targets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(id: u64, panel: &str, spec: EngineSpec, n_targets: usize) -> Pending {
        // These queue-shape tests never redeem tickets, so the receiver side
        // is dropped immediately.
        let (tx, _rx) = mpsc::channel();
        Pending {
            id,
            req: ImputeRequest::new(
                panel,
                spec,
                vec![TargetHaplotype::new(vec![-1, 0, 1]); n_targets],
            ),
            enqueued: Instant::now(),
            reply: tx,
            parts: None,
            span: None,
        }
    }

    fn pending_mint(id: u64, panel: &str, spec: EngineSpec, count: usize) -> Pending {
        let (tx, _rx) = mpsc::channel();
        Pending {
            id,
            req: ImputeRequest::new(panel, spec, RequestTargets::Mint { count, seed: 0 }),
            enqueued: Instant::now(),
            reply: tx,
            parts: None,
            span: None,
        }
    }

    #[test]
    fn drain_matching_respects_key_and_budget() {
        let mut st = QueueState::default();
        st.pending.push_back(pending(1, "a", EngineSpec::Event, 2));
        st.pending.push_back(pending(2, "b", EngineSpec::Event, 1));
        st.pending.push_back(pending(3, "a", EngineSpec::Rank1, 1));
        st.pending.push_back(pending(4, "a", EngineSpec::Event, 3));
        st.pending.push_back(pending(5, "a", EngineSpec::Event, 1));

        let mut group = Vec::new();
        // Budget 4, 1 target already in hand: takes #1 (2), skips #4 (would
        // overflow), takes #5 (1).
        let total = st.drain_matching(("a", EngineSpec::Event), &mut group, 1, 4);
        assert_eq!(total, 4);
        assert_eq!(
            group.iter().map(|p| p.id).collect::<Vec<_>>(),
            vec![1, 5]
        );
        // Non-matching and oversized requests stay queued, order preserved.
        assert_eq!(
            st.pending.iter().map(|p| p.id).collect::<Vec<_>>(),
            vec![2, 3, 4]
        );
    }

    #[test]
    fn streamed_requests_never_coalesce() {
        let mut st = QueueState::default();
        let mut p = pending(1, "a", EngineSpec::Event, 1);
        p.req = p.req.stream_windows(8, 2);
        st.pending.push_back(p);
        st.pending.push_back(pending(2, "a", EngineSpec::Event, 1));
        let mut group = Vec::new();
        let total = st.drain_matching(("a", EngineSpec::Event), &mut group, 1, 16);
        assert_eq!(total, 2, "only the plain request merges");
        assert_eq!(group.iter().map(|p| p.id).collect::<Vec<_>>(), vec![2]);
        assert_eq!(st.pending.len(), 1, "streamed request stays queued");
    }

    #[test]
    fn coalesce_policy_off_disables_merging() {
        assert!(CoalescePolicy::off().is_off());
        assert!(!CoalescePolicy::default().is_off());
        let mut st = QueueState::default();
        st.pending.push_back(pending(1, "a", EngineSpec::Event, 1));
        let mut group = Vec::new();
        let total = st.drain_matching(("a", EngineSpec::Event), &mut group, 1, 1);
        assert_eq!(total, 1);
        assert!(group.is_empty(), "budget 1 means the head runs alone");
    }

    #[test]
    fn drain_matching_charges_declared_mint_width() {
        // A deferred mint counts its declared width against the budget even
        // though no targets exist yet (they are minted in the worker pool).
        let mut st = QueueState::default();
        st.pending.push_back(pending_mint(1, "a", EngineSpec::Event, 3));
        st.pending.push_back(pending_mint(2, "a", EngineSpec::Event, 3));
        let mut group = Vec::new();
        let total = st.drain_matching(("a", EngineSpec::Event), &mut group, 1, 4);
        assert_eq!(total, 4, "only the first 3-wide mint fits a budget of 4");
        assert_eq!(group.iter().map(|p| p.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(st.pending.len(), 1);
        assert_eq!(RequestTargets::Mint { count: 3, seed: 0 }.declared_len(), 3);
        assert!(RequestTargets::Mint { count: 0, seed: 9 }.is_empty());
    }

    #[test]
    fn stats_mean_width_and_merge() {
        let mut s = ServiceStats::default();
        assert_eq!(s.mean_batch_width(), 0.0);
        s.batches = 4;
        s.coalesced_requests = 10;
        assert!((s.mean_batch_width() - 2.5).abs() < 1e-12);
        s.queue_wait_hist[3] = 5;
        s.cache_hits = 7;
        let t = ServiceStats {
            accepted: 1,
            shed_quota: 2,
            shed_deadline: 3,
            cache_hits: 1,
            cache_misses: 4,
            cache_evictions: 2,
            queue_wait_hist: {
                let mut h = [0u64; LATENCY_BUCKETS];
                h[3] = 2;
                h[9] = 1;
                h
            },
            ..ServiceStats::default()
        };
        let merged = s.merge(&t);
        assert_eq!(merged.batches, 4);
        assert_eq!(merged.accepted, 1);
        assert_eq!(merged.shed_quota, 2);
        assert_eq!(merged.shed_deadline, 3);
        assert_eq!(merged.cache_hits, 8);
        assert_eq!(merged.cache_misses, 4);
        assert_eq!(merged.cache_evictions, 2);
        assert_eq!(merged.queue_wait_hist[3], 7, "histograms sum element-wise");
        assert_eq!(merged.queue_wait_hist[9], 1);
    }

    #[test]
    fn merge_sums_recovery_counters_and_ors_degraded() {
        let a = ServiceStats {
            retried: 1,
            recovered_runs: 2,
            recovery_cycles: 100,
            degraded: false,
            ..ServiceStats::default()
        };
        let b = ServiceStats {
            retried: 3,
            recovered_runs: 1,
            recovery_cycles: 50,
            degraded: true,
            ..ServiceStats::default()
        };
        let m = a.merge(&b);
        assert_eq!(m.retried, 4);
        assert_eq!(m.recovered_runs, 3);
        assert_eq!(m.recovery_cycles, 150);
        assert!(m.degraded, "one degraded shard degrades the aggregate");
        assert!(!a.merge(&a).degraded);
    }

    #[test]
    fn degraded_service_stretches_the_wait_estimate() {
        let mut st = QueueState::default();
        st.note_service_time(0.010);
        st.pending.push_back(pending(1, "a", EngineSpec::Event, 1));
        let clean = st.estimated_wait_seconds(1);
        assert!(clean > 0.0);
        st.note_recovery(777, 1);
        assert!(st.stats.degraded);
        assert_eq!(st.stats.recovered_runs, 1);
        assert_eq!(st.stats.recovery_cycles, 777);
        let stretched = st.estimated_wait_seconds(1);
        assert!((stretched - clean * DEGRADED_WAIT_FACTOR).abs() < 1e-12);
        // The next clean run clears the flag; counters persist.
        st.note_recovery(0, 0);
        assert!(!st.stats.degraded);
        assert_eq!(st.stats.recovered_runs, 1);
        assert!((st.estimated_wait_seconds(1) - clean).abs() < 1e-12);
    }

    #[test]
    fn span_stamps_are_monotone_and_forward_filled() {
        let mut s = RequestSpan {
            admitted_us: 10,
            ..RequestSpan::default()
        };
        // An out-of-order stamp clamps up to the previous phase.
        s.mark_dequeued(4);
        assert_eq!(s.dequeued_us, 10);
        s.mark_minted(25);
        // The skipped prepare/run phases forward-fill at close-out.
        s.mark_responded(40);
        assert_eq!(s.prepared_us, 25);
        assert_eq!(s.run_us, 25);
        assert_eq!(s.responded_us, 40);
        let stamps = [
            s.admitted_us,
            s.dequeued_us,
            s.minted_us,
            s.prepared_us,
            s.run_us,
            s.responded_us,
        ];
        assert!(stamps.windows(2).all(|w| w[0] <= w[1]), "{stamps:?}");
    }

    #[test]
    fn service_time_feeds_the_histogram() {
        let mut st = QueueState::default();
        st.note_service_time(0.001); // 1000 µs -> bucket 9
        st.note_service_time(f64::NAN); // ignored
        assert_eq!(st.stats.service_hist[latency_bucket(1000)], 1);
        assert_eq!(st.stats.service_hist.iter().sum::<u64>(), 1);
    }

    #[test]
    fn token_bucket_spends_burst_then_refills_at_rate() {
        let mut st = QueueState::default();
        let quota = TenantQuota::new(0.0, 2.0);
        let t0 = Instant::now();
        assert!(st.take_token("acme", &quota, t0));
        assert!(st.take_token("acme", &quota, t0));
        // Burst spent; rate 0 never refills.
        assert!(!st.take_token("acme", &quota, t0));
        assert!(!st.take_token("acme", &quota, t0 + Duration::from_secs(3600)));
        // A different tenant has its own bucket.
        assert!(st.take_token("other", &quota, t0));

        // Positive rate refills over (simulated) time, capped at burst.
        let quota = TenantQuota::new(1.0, 2.0);
        assert!(st.take_token("slow", &quota, t0));
        assert!(st.take_token("slow", &quota, t0));
        assert!(!st.take_token("slow", &quota, t0));
        assert!(st.take_token("slow", &quota, t0 + Duration::from_millis(1500)));
        assert!(!st.take_token("slow", &quota, t0 + Duration::from_millis(1600)));
    }

    #[test]
    fn wait_estimate_tracks_depth_and_ewma() {
        let mut st = QueueState::default();
        assert_eq!(st.estimated_wait_seconds(2), 0.0, "no history, no estimate");
        st.note_service_time(0.010);
        assert!((st.ewma_service_seconds - 0.010).abs() < 1e-12);
        st.note_service_time(0.020);
        // 0.3 * 0.020 + 0.7 * 0.010 = 0.013
        assert!((st.ewma_service_seconds - 0.013).abs() < 1e-12);
        st.note_service_time(f64::NAN);
        st.note_service_time(-1.0);
        assert!((st.ewma_service_seconds - 0.013).abs() < 1e-12, "garbage ignored");

        st.pending.push_back(pending(1, "a", EngineSpec::Rank1, 1));
        st.pending.push_back(pending(2, "a", EngineSpec::Rank1, 1));
        let est = st.estimated_wait_seconds(2);
        assert!((est - 2.0 * 0.013 / 2.0).abs() < 1e-12);
        assert!(st.estimated_wait_seconds(1) > est);
    }
}
