//! JSONL protocol + the stdin/stdout frontend — `poets-impute serve`.
//!
//! One request per input line, one (or more, for streamed requests)
//! response documents per request, responses in request order.  The exact
//! same documents travel over TCP with a `u32` length prefix instead of a
//! newline delimiter ([`super::net`]); both frontends share this module's
//! parser and response builders, so a TCP response body is byte-identical
//! to the stdin response line for the same request.
//!
//! ## Request line
//!
//! ```json
//! {"id": 1, "panel": "synth:hap=8,mark=21,annot=0.2,seed=7",
//!  "engine": "event", "synth_targets": 2, "target_seed": 9,
//!  "tenant": "acme", "deadline_ms": 250}
//! ```
//!
//! * `panel` (string, required) — registry name: a registered panel, a
//!   `synth:hap=..,mark=..` spec, or a file-backed `vcf:<path>` /
//!   `packed:<path>` spec (see [`super::registry`]).  A missing or corrupt
//!   file fails that request in-band (`serve-error/v1`), like any other
//!   bad request — never a worker panic.
//! * `engine` (string, default `"event"`) — any `EngineSpec` spelling.
//! * `targets` (array of arrays) — observation vectors, one per target:
//!   `-1` untyped, `0`/`1` typed alleles.  Mutually exclusive with:
//! * `synth_targets` (int) + `target_seed` (int, default 0) — mint targets
//!   server-side (testing/load-gen): from the panel's synthetic recipe when
//!   it has one, otherwise Li & Stephens mosaics of the panel itself on a
//!   1-in-10 annotation grid (so file-backed panels work too).  Minting is
//!   **deferred to the worker pool** (`RequestTargets::Mint`): the stream
//!   reader never resolves the panel, so a slow file-backed load can't
//!   head-of-line block admission of later lines; mint failures (bad spec,
//!   over-cap count) come back as in-band `serve-error/v1` lines like any
//!   other per-request failure.
//! * `tenant` (string, optional) — names the token bucket this request
//!   spends from when the service runs with per-tenant quotas
//!   ([`super::TenantQuota`]); an empty bucket sheds with a `quota:` error.
//! * `deadline_ms` (int, optional) — latency budget; shed with a
//!   `deadline:` error at admission when the queue-age estimate exceeds it,
//!   or worker-side when the true age (queue wait + mint) overran.
//! * `window` (int) + `overlap` (int, default 0) + `stream` (bool,
//!   optional marker) — run windowed and **stream** each window's
//!   core-span dosage rows as a `serve-report-part/v1` document the moment
//!   it completes, followed by a terminal manifest (the full
//!   `serve-report/v1` minus `dosages`, plus `"parts"`); see
//!   [`super::report`] for both schemas.
//! * `spans` (bool, optional) — opt into the per-request phase timeline:
//!   the response's `serve` section gains a `spans` object with monotone
//!   microsecond offsets (admitted → dequeued → minted → prepared → run →
//!   responded) plus `coalesced_with` / `merged_wave`; see
//!   [`super::report`].
//! * `id` (int, default: 1-based line number) — echoed in every response
//!   document for this request.
//!
//! ## Admin verbs
//!
//! `{"stats": true}` answers with a `serve-stats/v1` snapshot (totals +
//! per-shard queue depth/counters).  `{"shutdown": true}` acknowledges
//! with a draining `serve-stats/v1`, stops reading further input, and
//! drains everything already admitted — the graceful-shutdown path for
//! both frontends (a supervisor closing stdin is the SIGTERM-equivalent
//! for the pipe transport; std has no portable signal hook).
//!
//! ## Response documents
//!
//! On success, the `poets-impute/serve-report/v1` document (see
//! [`super::report`]) plus `"id"` and `"ok": true`.  On failure,
//! `{"schema": "poets-impute/serve-error/v1", "id": .., "ok": false,
//! "error": ".."}` — a bad request fails in-band and the stream keeps
//! serving; only transport errors (unreadable input, broken pipe) abort.
//! The error string's prefix is the shed taxonomy: `admission:` (queue
//! full / malformed), `quota:` (tenant bucket empty), `deadline:` (budget
//! busted) — anything else is an execution failure.
//!
//! Responses are emitted in request order, but requests are submitted as
//! they are read — the service coalesces and executes them concurrently,
//! so piping a burst of same-panel lines exercises the real batching path.

use std::collections::VecDeque;
use std::io::{BufRead, Write};

use crate::model::panel::TargetHaplotype;
use crate::session::EngineSpec;
use crate::util::json::Json;

use super::queue::{RequestTargets, ServePart, Ticket};
use super::{ImputeRequest, ServeReport, ShardedService};

/// What a stream session did (the CLI prints this to stderr at EOF).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamSummary {
    pub requests: u64,
    pub ok: u64,
    pub failed: u64,
}

/// One parsed input line.
pub(crate) enum Verb {
    /// An imputation request to submit.
    Impute(Box<ImputeRequest>),
    /// `{"stats": true}` — answer with a `serve-stats/v1` snapshot.
    Stats,
    /// `{"shutdown": true}` — acknowledge, stop accepting, drain, exit.
    Shutdown,
}

/// An in-order response slot: answered immediately (parse/admission error,
/// admin verb) or waiting on a service ticket (streamed tickets track how
/// many parts have been emitted so far).
enum Slot {
    Ready(Json),
    InFlight(i64, Ticket),
    Streaming(i64, Ticket, usize),
}

/// Drive the service from `input` to `output` until EOF or a `shutdown`
/// verb.  Per-request failures are in-band error lines; only transport
/// failures return `Err`.
pub fn serve_stream<R: BufRead, W: Write>(
    service: &ShardedService,
    input: R,
    mut output: W,
) -> Result<StreamSummary, String> {
    let mut summary = StreamSummary::default();
    let mut slots: VecDeque<Slot> = VecDeque::new();
    let mut line_no = 0i64;
    let mut draining = false;

    for line in input.lines() {
        let line = line.map_err(|e| format!("reading request stream: {e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        line_no += 1;
        summary.requests += 1;
        let slot = match parse_line(&line, line_no) {
            Ok((id, Verb::Impute(req))) => loop {
                match service.submit((*req).clone()) {
                    Ok(ticket) if ticket.is_streaming() => break Slot::Streaming(id, ticket, 0),
                    Ok(ticket) => break Slot::InFlight(id, ticket),
                    // Backpressure, not failure: this reader is the only
                    // submitter of these slots, so when the queue is full we
                    // block on our own head-of-line response (freeing queue
                    // space) and resubmit, instead of failing requests a
                    // blocking pipe was happy to wait for.
                    Err(e) if e.starts_with("admission: queue full") && !slots.is_empty() => {
                        if let Some(lines) = pop_ready(&mut slots, &mut summary, true) {
                            write_lines(&mut output, &lines)?;
                        }
                    }
                    Err(e) => {
                        summary.failed += 1;
                        break Slot::Ready(error_json(id, &e));
                    }
                }
            },
            Ok((id, Verb::Stats)) => {
                summary.ok += 1;
                Slot::Ready(stats_json(id, service, false))
            }
            Ok((id, Verb::Shutdown)) => {
                summary.ok += 1;
                draining = true;
                Slot::Ready(stats_json(id, service, true))
            }
            Err((id, e)) => {
                summary.failed += 1;
                Slot::Ready(error_json(id, &e))
            }
        };
        slots.push_back(slot);
        // Opportunistically flush responses (and streamed parts) that are
        // already done, in order, so a long-lived pipe sees answers without
        // waiting for EOF.
        while let Some(lines) = pop_ready(&mut slots, &mut summary, false) {
            write_lines(&mut output, &lines)?;
        }
        if draining {
            break;
        }
    }
    // EOF (or shutdown verb): block for everything still in flight.
    while let Some(lines) = pop_ready(&mut slots, &mut summary, true) {
        write_lines(&mut output, &lines)?;
    }
    Ok(summary)
}

/// Emit the head slot's newly-available response documents, popping the
/// slot once its final document is out.  `None` means no progress is
/// possible right now (head still in flight and `block` is false) or the
/// queue is empty.  A streamed head may yield parts without being popped;
/// the returned list is never empty.
fn pop_ready(
    slots: &mut VecDeque<Slot>,
    summary: &mut StreamSummary,
    block: bool,
) -> Option<Vec<Json>> {
    match slots.front_mut()? {
        Slot::Ready(_) => match slots.pop_front() {
            Some(Slot::Ready(json)) => Some(vec![json]),
            _ => unreachable!("peeked Ready"),
        },
        Slot::InFlight(id, ticket) => {
            let result = if block {
                let (id, ticket) = match slots.pop_front() {
                    Some(Slot::InFlight(id, t)) => (id, t),
                    _ => unreachable!("peeked InFlight"),
                };
                return Some(vec![result_json(id, ticket.wait(), summary)]);
            } else {
                ticket.try_wait()?
            };
            let id = *id;
            slots.pop_front();
            Some(vec![result_json(id, result, summary)])
        }
        Slot::Streaming(id, ticket, emitted) => {
            let id = *id;
            let mut lines = Vec::new();
            if block {
                let (ticket, mut emitted) = match slots.pop_front() {
                    Some(Slot::Streaming(_, t, n)) => (t, n),
                    _ => unreachable!("peeked Streaming"),
                };
                while let Some(part) = ticket.recv_part() {
                    lines.push(part_json(id, &part));
                    emitted += 1;
                }
                lines.push(stream_final_json(id, ticket.wait(), emitted, summary));
                return Some(lines);
            }
            while let Some(part) = ticket.try_recv_part() {
                lines.push(part_json(id, &part));
                *emitted += 1;
            }
            if let Some(result) = ticket.try_wait() {
                // The worker sends every part before replying, so once the
                // reply is in, one more drain empties the part channel.
                while let Some(part) = ticket.try_recv_part() {
                    lines.push(part_json(id, &part));
                    *emitted += 1;
                }
                let n = *emitted;
                slots.pop_front();
                lines.push(stream_final_json(id, result, n, summary));
                Some(lines)
            } else if lines.is_empty() {
                None
            } else {
                Some(lines)
            }
        }
    }
}

fn write_lines<W: Write>(output: &mut W, lines: &[Json]) -> Result<(), String> {
    for json in lines {
        writeln!(output, "{}", json.render()).map_err(|e| format!("writing response: {e}"))?;
    }
    output
        .flush()
        .map_err(|e| format!("flushing response: {e}"))
}

/// Final document for a plain request: the full report or an error.
pub(crate) fn result_json(
    id: i64,
    result: Result<ServeReport, String>,
    summary: &mut StreamSummary,
) -> Json {
    match result {
        Ok(report) => {
            summary.ok += 1;
            report_json(id, &report)
        }
        Err(e) => {
            summary.failed += 1;
            error_json(id, &e)
        }
    }
}

/// Final document for a streamed request: the terminal manifest (report
/// minus dosages, plus the part count) or an error.
pub(crate) fn stream_final_json(
    id: i64,
    result: Result<ServeReport, String>,
    parts_emitted: usize,
    summary: &mut StreamSummary,
) -> Json {
    match result {
        Ok(report) => {
            summary.ok += 1;
            manifest_json(id, &report, parts_emitted)
        }
        Err(e) => {
            summary.failed += 1;
            error_json(id, &e)
        }
    }
}

/// `serve-report/v1` success document.
pub(crate) fn report_json(id: i64, report: &ServeReport) -> Json {
    let mut j = report.to_json();
    j.set("id", id).set("ok", true);
    j
}

/// `serve-error/v1` document.  The error prefix is the shed taxonomy
/// (`admission:` / `quota:` / `deadline:`), anything else is an execution
/// failure.
pub(crate) fn error_json(id: i64, error: &str) -> Json {
    let mut j = Json::obj();
    j.set("schema", "poets-impute/serve-error/v1")
        .set("id", id)
        .set("ok", false)
        .set("error", error);
    j
}

/// `serve-report-part/v1` document: one streamed window's core-span rows.
pub(crate) fn part_json(id: i64, part: &ServePart) -> Json {
    let mut dosages = Json::Arr(Vec::new());
    for row in &part.rows {
        dosages.push(Json::Arr(
            row.iter().map(|&d| Json::Num(f64::from(d))).collect(),
        ));
    }
    let mut j = Json::obj();
    j.set("schema", "poets-impute/serve-report-part/v1")
        .set("id", id)
        .set("ok", true)
        .set("request_id", part.request_id)
        .set("window", part.window_index)
        .set("n_windows", part.n_windows)
        .set("core_start", part.core_start)
        .set("core_end", part.core_end)
        .set("dosages", dosages);
    j
}

/// Terminal manifest for a streamed request: the `serve-report/v1`
/// document minus its `dosages` matrix (already delivered as parts), plus
/// `"parts"` (how many part documents preceded it) and `"streamed": true`.
pub(crate) fn manifest_json(id: i64, report: &ServeReport, parts_emitted: usize) -> Json {
    let mut j = report.to_json();
    j.remove("dosages");
    j.set("parts", parts_emitted)
        .set("streamed", true)
        .set("id", id)
        .set("ok", true);
    j
}

/// `serve-stats/v1` snapshot: aggregate totals plus per-shard queue depth
/// and counters.  `draining` marks the shutdown acknowledgement.
pub(crate) fn stats_json(id: i64, service: &ShardedService, draining: bool) -> Json {
    let hist = |h: &[u64]| Json::Arr(h.iter().map(|&c| Json::Int(c as i64)).collect());
    let stats_obj = |s: &super::ServiceStats| {
        let mut t = Json::obj();
        t.set("accepted", s.accepted)
            .set("rejected", s.rejected)
            .set("completed", s.completed)
            .set("failed", s.failed)
            .set("batches", s.batches)
            .set("coalesced_requests", s.coalesced_requests)
            .set("merged_waves", s.merged_waves)
            .set("shed_quota", s.shed_quota)
            .set("shed_deadline", s.shed_deadline)
            .set("mean_batch_width", s.mean_batch_width())
            .set("cache_hits", s.cache_hits)
            .set("cache_misses", s.cache_misses)
            .set("cache_evictions", s.cache_evictions)
            // Fault plane: fresh-engine retries, runs that recovered from
            // scheduled tile deaths, and whether the last event run on any
            // shard was still recovering (admission stretches estimates).
            .set("retried", s.retried)
            .set("recovered_runs", s.recovered_runs)
            .set("recovery_cycles", s.recovery_cycles)
            .set("degraded", s.degraded)
            // Log2-µs buckets: index i counts values in [2^i, 2^(i+1)) µs
            // (see crate::obs::bucket_bounds), saturating at the last.
            .set("queue_wait_hist", hist(&s.queue_wait_hist))
            .set("service_hist", hist(&s.service_hist));
        t
    };
    let totals = service.stats();
    let mut per_shard = Json::Arr(Vec::new());
    for snap in service.shard_snapshots() {
        let mut s = stats_obj(&snap.stats);
        s.set("shard", snap.shard)
            .set("queue_depth", snap.queue_depth);
        per_shard.push(s);
    }
    let mut j = Json::obj();
    j.set("schema", "poets-impute/serve-stats/v1")
        .set("id", id)
        .set("ok", true)
        .set("shards", service.n_shards())
        .set("panels_cached", service.registry().len())
        .set("totals", stats_obj(&totals))
        .set("per_shard", per_shard);
    if draining {
        j.set("draining", true);
    }
    j
}

const KNOWN_KEYS: [&str; 14] = [
    "id",
    "panel",
    "engine",
    "targets",
    "synth_targets",
    "target_seed",
    "tenant",
    "deadline_ms",
    "window",
    "overlap",
    "stream",
    "spans",
    "stats",
    "shutdown",
];

/// Parse one request line into a [`Verb`].  Errors carry the best-known
/// request id so the error response still correlates with the input line.
/// Parsing never touches the panel registry: `synth_targets` becomes a
/// deferred [`RequestTargets::Mint`] executed in the worker pool.
pub(crate) fn parse_line(line: &str, line_no: i64) -> Result<(i64, Verb), (i64, String)> {
    let j = Json::parse(line).map_err(|e| (line_no, format!("bad request JSON: {e}")))?;
    // Client ids are echoed verbatim (negative ids included), so they stay
    // i64 end to end instead of wrapping through a u64 cast.
    let id = j.get("id").and_then(Json::as_i64).unwrap_or(line_no);
    let fail = |e: String| (id, e);

    let Json::Obj(pairs) = &j else {
        return Err(fail("request line must be a JSON object".into()));
    };
    for (key, _) in pairs {
        if !KNOWN_KEYS.contains(&key.as_str()) {
            return Err(fail(format!(
                "unknown request key {key:?} (expected one of {KNOWN_KEYS:?})"
            )));
        }
    }

    // Admin verbs: exclusive of everything but "id".
    for (verb, variant) in [("stats", Verb::Stats), ("shutdown", Verb::Shutdown)] {
        if let Some(v) = j.get(verb) {
            if v.as_bool() != Some(true) {
                return Err(fail(format!("\"{verb}\" must be true when present")));
            }
            if pairs.iter().any(|(k, _)| k != verb && k != "id") {
                return Err(fail(format!("\"{verb}\" takes no other keys")));
            }
            return Ok((id, variant));
        }
    }

    let panel = j
        .get("panel")
        .and_then(Json::as_str)
        .ok_or_else(|| fail("request needs a \"panel\" string".into()))?
        .to_string();
    let engine: EngineSpec = j
        .get("engine")
        .and_then(Json::as_str)
        .unwrap_or("event")
        .parse()
        .map_err(fail)?;

    let targets = match (j.get("targets"), j.get("synth_targets")) {
        (Some(_), Some(_)) => {
            return Err(fail(
                "\"targets\" and \"synth_targets\" are mutually exclusive".into(),
            ));
        }
        (Some(t), None) => RequestTargets::Explicit(parse_targets(t).map_err(fail)?),
        (None, Some(n)) => {
            let count = n
                .as_usize()
                .ok_or_else(|| fail("\"synth_targets\" must be a non-negative int".into()))?;
            let seed = j
                .get("target_seed")
                .and_then(Json::as_i64)
                .unwrap_or(0) as u64;
            RequestTargets::Mint { count, seed }
        }
        (None, None) => {
            return Err(fail(
                "request needs \"targets\" or \"synth_targets\"".into(),
            ));
        }
    };

    let mut req = ImputeRequest::new(panel, engine, targets);
    if let Some(t) = j.get("tenant") {
        let tenant = t
            .as_str()
            .ok_or_else(|| fail("\"tenant\" must be a string".into()))?;
        req = req.tenant(tenant);
    }
    if let Some(d) = j.get("deadline_ms") {
        let ms = d
            .as_i64()
            .filter(|&ms| ms >= 0)
            .ok_or_else(|| fail("\"deadline_ms\" must be a non-negative int".into()))?;
        req = req.deadline_ms(ms as u64);
    }
    if let Some(s) = j.get("spans") {
        if s.as_bool() != Some(true) {
            return Err(fail("\"spans\" must be true when present".into()));
        }
        req = req.with_spans();
    }
    match (j.get("window"), j.get("overlap"), j.get("stream")) {
        (None, None, None) => {}
        (None, _, _) => {
            return Err(fail(
                "\"stream\"/\"overlap\" need a \"window\" length".into(),
            ));
        }
        (Some(w), overlap, stream) => {
            if let Some(s) = stream {
                if s.as_bool() != Some(true) {
                    return Err(fail("\"stream\" must be true when present".into()));
                }
            }
            let window = w
                .as_usize()
                .filter(|&w| w >= 2)
                .ok_or_else(|| fail("\"window\" must be an int >= 2".into()))?;
            let overlap = match overlap {
                None => 0,
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| fail("\"overlap\" must be a non-negative int".into()))?,
            };
            req = req.stream_windows(window, overlap);
        }
    }

    Ok((id, Verb::Impute(Box::new(req))))
}

/// Observation vectors: arrays of `-1 | 0 | 1`, one per target.
fn parse_targets(j: &Json) -> Result<Vec<TargetHaplotype>, String> {
    let rows = j
        .as_arr()
        .ok_or("\"targets\" must be an array of observation arrays")?;
    let mut targets = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let obs_row = row
            .as_arr()
            .ok_or_else(|| format!("target {i} must be an array of -1|0|1"))?;
        let mut obs = Vec::with_capacity(obs_row.len());
        for v in obs_row {
            let o = v
                .as_i64()
                .filter(|o| (-1..=1).contains(o))
                .ok_or_else(|| format!("target {i}: observations must be -1|0|1"))?;
            obs.push(o as i8);
        }
        targets.push(TargetHaplotype::new(obs));
    }
    Ok(targets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{PanelRegistry, ServeConfig};
    use std::sync::Arc;

    const PANEL: &str = "synth:hap=8,mark=21,annot=0.2,seed=7";

    fn sharded(cfg: ServeConfig, shards: usize) -> ShardedService {
        ShardedService::start(Arc::new(PanelRegistry::new()), cfg, shards)
    }

    fn run(input: &str) -> (StreamSummary, Vec<Json>) {
        let service = sharded(ServeConfig::default(), 1);
        let mut out = Vec::new();
        let summary = serve_stream(&service, input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines = text
            .lines()
            .map(|l| Json::parse(l).expect("every response line is valid JSON"))
            .collect();
        (summary, lines)
    }

    #[test]
    fn three_requests_three_wellformed_responses() {
        let l1 = format!(r#"{{"id":1,"panel":"{PANEL}","engine":"baseline","synth_targets":2}}"#);
        let l2 = format!(
            r#"{{"id":2,"panel":"{PANEL}","engine":"rank1","synth_targets":1,"target_seed":3}}"#
        );
        let l3 = format!(r#"{{"id":3,"panel":"{PANEL}","engine":"event","synth_targets":1}}"#);
        let input = format!("{l1}\n{l2}\n{l3}\n");
        let (summary, lines) = run(&input);
        assert_eq!(summary.requests, 3);
        assert_eq!(summary.ok, 3);
        assert_eq!(summary.failed, 0);
        assert_eq!(lines.len(), 3);
        for (i, j) in lines.iter().enumerate() {
            assert_eq!(
                j.get("schema").unwrap().as_str(),
                Some("poets-impute/serve-report/v1")
            );
            assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
            assert_eq!(j.get("id").unwrap().as_i64(), Some(i as i64 + 1));
            assert!(!j.get("dosages").unwrap().as_arr().unwrap().is_empty());
        }
        // Responses preserve request order.
        assert_eq!(lines[0].get("engine").unwrap().as_str(), Some("baseline"));
        assert_eq!(lines[2].get("engine").unwrap().as_str(), Some("event"));
    }

    #[test]
    fn explicit_targets_and_blank_lines() {
        let obs: Vec<String> = (0..21)
            .map(|m| (if m % 5 == 0 { "1" } else { "-1" }).to_string())
            .collect();
        let input = format!(
            "\n{{\"panel\":\"{PANEL}\",\"engine\":\"baseline\",\"targets\":[[{}]]}}\n\n",
            obs.join(",")
        );
        let (summary, lines) = run(&input);
        assert_eq!(summary.requests, 1);
        assert_eq!(summary.ok, 1);
        assert_eq!(lines.len(), 1);
        // Default id = 1-based request number.
        assert_eq!(lines[0].get("id").unwrap().as_i64(), Some(1));
        let d = lines[0].get("dosages").unwrap().as_arr().unwrap();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].as_arr().unwrap().len(), 21);
    }

    #[test]
    fn bad_lines_fail_in_band_and_the_stream_continues() {
        let input = format!(
            "not json at all\n\
             {{\"id\":7,\"panel\":\"{PANEL}\",\"engine\":\"warp\",\"synth_targets\":1}}\n\
             {{\"id\":8,\"panel\":\"{PANEL}\",\"bogus\":1,\"synth_targets\":1}}\n\
             {{\"id\":9,\"panel\":\"{PANEL}\",\"synth_targets\":1}}\n"
        );
        let (summary, lines) = run(&input);
        assert_eq!(summary.requests, 4);
        assert_eq!(summary.ok, 1);
        assert_eq!(summary.failed, 3);
        assert_eq!(lines.len(), 4);
        for j in &lines[..3] {
            assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
            assert_eq!(
                j.get("schema").unwrap().as_str(),
                Some("poets-impute/serve-error/v1")
            );
            assert!(j.get("error").unwrap().as_str().is_some());
        }
        assert_eq!(lines[1].get("id").unwrap().as_i64(), Some(7));
        assert_eq!(lines[2].get("id").unwrap().as_i64(), Some(8));
        assert_eq!(lines[3].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(lines[3].get("id").unwrap().as_i64(), Some(9));
    }

    #[test]
    fn backpressure_blocks_instead_of_shedding_for_pipes() {
        // Capacity 1, one worker, eight lines: the reader must throttle on
        // its own in-flight responses, so a blocking pipe never sees
        // spurious "queue full" failures.
        let service = sharded(ServeConfig::default().workers(1).queue_capacity(1), 1);
        let mut input = String::new();
        for i in 0..8 {
            input.push_str(&format!(
                r#"{{"id":{i},"panel":"{PANEL}","engine":"rank1","synth_targets":1}}"#
            ));
            input.push('\n');
        }
        let mut out = Vec::new();
        let summary = serve_stream(&service, input.as_bytes(), &mut out).unwrap();
        assert_eq!(summary.requests, 8);
        assert_eq!(summary.ok, 8, "queue-full must backpressure, not shed");
        assert_eq!(summary.failed, 0);
    }

    #[test]
    fn negative_ids_echo_verbatim() {
        let input = format!(r#"{{"id":-3,"panel":"{PANEL}","engine":"rank1","synth_targets":1}}"#)
            + "\n";
        let (summary, lines) = run(&input);
        assert_eq!(summary.ok, 1);
        assert_eq!(lines[0].get("id").unwrap().as_i64(), Some(-3));
    }

    #[test]
    fn out_of_range_synth_spec_fails_in_band() {
        // A spec that would trip panelgen asserts must come back as an
        // in-band error line, not kill the stream (or a pool worker).
        let input = concat!(
            r#"{"id":1,"panel":"synth:hap=8,mark=21,maf=0.9","synth_targets":1}"#,
            "\n",
            r#"{"id":2,"panel":"synth:hap=8,mark=21,annot=0.2,seed=7","engine":"rank1","synth_targets":1}"#,
            "\n"
        );
        let (summary, lines) = run(input);
        assert_eq!(summary.failed, 1);
        assert_eq!(summary.ok, 1);
        assert!(
            lines[0]
                .get("error")
                .unwrap()
                .as_str()
                .unwrap()
                .contains("synth spec")
        );
        assert_eq!(lines[1].get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn targets_must_be_valid_observations() {
        let input = format!(r#"{{"panel":"{PANEL}","targets":[[0,2,1]]}}"#) + "\n";
        let (summary, lines) = run(&input);
        assert_eq!(summary.failed, 1);
        assert!(
            lines[0]
                .get("error")
                .unwrap()
                .as_str()
                .unwrap()
                .contains("-1|0|1")
        );
    }

    #[test]
    fn stats_verb_reports_totals_and_per_shard_counters() {
        let input = format!(
            "{{\"id\":1,\"panel\":\"{PANEL}\",\"engine\":\"rank1\",\"synth_targets\":1}}\n\
             {{\"id\":2,\"stats\":true}}\n"
        );
        let service = sharded(ServeConfig::default(), 2);
        let mut out = Vec::new();
        let summary = serve_stream(&service, input.as_bytes(), &mut out).unwrap();
        assert_eq!(summary.requests, 2);
        assert_eq!(summary.ok, 2);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        let stats = lines
            .iter()
            .find(|j| j.get("schema").unwrap().as_str() == Some("poets-impute/serve-stats/v1"))
            .expect("stats response present");
        assert_eq!(stats.get("id").unwrap().as_i64(), Some(2));
        assert_eq!(stats.get("shards").unwrap().as_i64(), Some(2));
        let totals = stats.get("totals").unwrap();
        assert_eq!(totals.get("accepted").unwrap().as_i64(), Some(1));
        assert_eq!(totals.get("shed_quota").unwrap().as_i64(), Some(0));
        // The served request built one engine: a cache miss, zero hits, and
        // one sample in each latency histogram.
        assert_eq!(totals.get("cache_misses").unwrap().as_i64(), Some(1));
        assert_eq!(totals.get("cache_hits").unwrap().as_i64(), Some(0));
        assert_eq!(totals.get("cache_evictions").unwrap().as_i64(), Some(0));
        for key in ["queue_wait_hist", "service_hist"] {
            let h = totals.get(key).unwrap().as_arr().unwrap();
            assert_eq!(h.len(), crate::obs::LATENCY_BUCKETS, "{key} length");
            let total: i64 = h.iter().map(|b| b.as_i64().unwrap()).sum();
            assert_eq!(total, 1, "{key} counts the one served request");
        }
        // A clean run never marks the service degraded, but the recovery
        // keys are always present in the schema (both transports share this
        // assembler).
        assert_eq!(totals.get("retried").unwrap().as_i64(), Some(0));
        assert_eq!(totals.get("recovered_runs").unwrap().as_i64(), Some(0));
        assert_eq!(totals.get("degraded").unwrap().as_bool(), Some(false));
        let per_shard = stats.get("per_shard").unwrap().as_arr().unwrap();
        assert_eq!(per_shard.len(), 2);
        for s in per_shard {
            assert!(s.get("queue_depth").unwrap().as_i64().is_some());
            assert!(s.get("merged_waves").unwrap().as_i64().is_some());
            assert!(s.get("cache_hits").unwrap().as_i64().is_some());
            assert!(s.get("degraded").unwrap().as_bool().is_some());
        }
        assert!(stats.get("draining").is_none());
    }

    #[test]
    fn spans_key_opts_into_the_timeline() {
        let input = format!(
            "{{\"id\":1,\"panel\":\"{PANEL}\",\"engine\":\"rank1\",\"synth_targets\":1,\
             \"spans\":true}}\n\
             {{\"id\":2,\"panel\":\"{PANEL}\",\"engine\":\"rank1\",\"synth_targets\":1}}\n\
             {{\"id\":3,\"panel\":\"{PANEL}\",\"synth_targets\":1,\"spans\":false}}\n"
        );
        let (summary, lines) = run(&input);
        assert_eq!(summary.ok, 2);
        assert_eq!(summary.failed, 1, "\"spans\": false is rejected");
        let spans = lines[0]
            .get("serve")
            .unwrap()
            .get("spans")
            .expect("opted-in response carries spans");
        let order = [
            "admitted_us",
            "dequeued_us",
            "minted_us",
            "prepared_us",
            "run_us",
            "responded_us",
        ];
        let mut prev = -1i64;
        for key in order {
            let v = spans.get(key).unwrap().as_i64().unwrap();
            assert!(v >= prev, "{key} must not regress (prev {prev}, got {v})");
            prev = v;
        }
        assert!(spans.get("coalesced_with").unwrap().as_i64().unwrap() >= 1);
        assert!(spans.get("merged_wave").unwrap().as_bool().is_some());
        assert!(
            lines[1].get("serve").unwrap().get("spans").is_none(),
            "spans stay opt-in"
        );
        assert!(
            lines[2]
                .get("error")
                .unwrap()
                .as_str()
                .unwrap()
                .contains("spans"),
        );
    }

    #[test]
    fn shutdown_verb_acknowledges_drains_and_stops_reading() {
        // The line after "shutdown" must never be read: 2 requests total.
        let input = format!(
            "{{\"id\":1,\"panel\":\"{PANEL}\",\"engine\":\"rank1\",\"synth_targets\":1}}\n\
             {{\"id\":2,\"shutdown\":true}}\n\
             {{\"id\":3,\"panel\":\"{PANEL}\",\"engine\":\"rank1\",\"synth_targets\":1}}\n"
        );
        let service = sharded(ServeConfig::default(), 1);
        let mut out = Vec::new();
        let summary = serve_stream(&service, input.as_bytes(), &mut out).unwrap();
        assert_eq!(summary.requests, 2, "input after shutdown is not consumed");
        assert_eq!(summary.ok, 2);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        // In-order: request 1's report, then the draining ack.
        assert_eq!(lines.len(), 2);
        assert_eq!(
            lines[0].get("schema").unwrap().as_str(),
            Some("poets-impute/serve-report/v1")
        );
        assert_eq!(lines[1].get("draining").unwrap().as_bool(), Some(true));
        // The already-admitted request completed (drained, not dropped).
        let stats = service.shutdown();
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn streamed_request_emits_parts_then_manifest() {
        let panel = "synth:hap=8,mark=41,annot=0.2,seed=23";
        let input = format!(
            "{{\"id\":5,\"panel\":\"{panel}\",\"engine\":\"rank1\",\"synth_targets\":2,\
             \"window\":16,\"overlap\":4,\"stream\":true}}\n"
        );
        let (summary, lines) = run(&input);
        assert_eq!(summary.ok, 1);
        assert!(lines.len() >= 3, "expected >= 2 parts + manifest");
        let (manifest, parts) = lines.split_last().unwrap();
        let mut markers = 0usize;
        for (i, p) in parts.iter().enumerate() {
            assert_eq!(
                p.get("schema").unwrap().as_str(),
                Some("poets-impute/serve-report-part/v1")
            );
            assert_eq!(p.get("id").unwrap().as_i64(), Some(5));
            assert_eq!(p.get("window").unwrap().as_usize(), Some(i));
            let rows = p.get("dosages").unwrap().as_arr().unwrap();
            assert_eq!(rows.len(), 2);
            let width = rows[0].as_arr().unwrap().len();
            assert_eq!(
                p.get("core_end").unwrap().as_usize().unwrap()
                    - p.get("core_start").unwrap().as_usize().unwrap(),
                width
            );
            markers += width;
        }
        assert_eq!(markers, 41, "parts cover the whole marker axis");
        assert_eq!(
            manifest.get("schema").unwrap().as_str(),
            Some("poets-impute/serve-report/v1")
        );
        assert!(manifest.get("dosages").is_none(), "manifest sheds the matrix");
        assert_eq!(manifest.get("parts").unwrap().as_usize(), Some(parts.len()));
        assert_eq!(manifest.get("streamed").unwrap().as_bool(), Some(true));
        assert_eq!(manifest.get("id").unwrap().as_i64(), Some(5));
    }

    #[test]
    fn tenant_and_deadline_fields_parse_and_shed_in_band() {
        // Quota rate 0 / burst 1: the second "acme" line sheds with quota:.
        let service = sharded(ServeConfig::default().workers(1).tenant_quota(0.0, 1.0), 1);
        let input = format!(
            "{{\"id\":1,\"panel\":\"{PANEL}\",\"engine\":\"rank1\",\"synth_targets\":1,\
             \"tenant\":\"acme\"}}\n\
             {{\"id\":2,\"panel\":\"{PANEL}\",\"engine\":\"rank1\",\"synth_targets\":1,\
             \"tenant\":\"acme\"}}\n\
             {{\"id\":3,\"panel\":\"{PANEL}\",\"engine\":\"rank1\",\"synth_targets\":1,\
             \"deadline_ms\":0}}\n"
        );
        let mut out = Vec::new();
        let summary = serve_stream(&service, input.as_bytes(), &mut out).unwrap();
        assert_eq!(summary.requests, 3);
        assert_eq!(summary.ok, 1);
        assert_eq!(summary.failed, 2);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(lines[0].get("ok").unwrap().as_bool(), Some(true));
        let quota_err = lines[1].get("error").unwrap().as_str().unwrap();
        assert!(quota_err.starts_with("quota:"), "{quota_err}");
        let deadline_err = lines[2].get("error").unwrap().as_str().unwrap();
        assert!(deadline_err.starts_with("deadline:"), "{deadline_err}");
        let stats = service.shutdown();
        assert_eq!(stats.shed_quota, 1);
        assert_eq!(stats.shed_deadline, 1);
    }

    #[test]
    fn malformed_admin_and_stream_keys_error_in_band() {
        let cases = [
            (r#"{"stats":1}"#, "must be true"),
            (r#"{"stats":true,"panel":"x"}"#, "no other keys"),
            (r#"{"shutdown":false}"#, "must be true"),
            (r#"{"panel":"x","synth_targets":1,"overlap":2}"#, "need a \"window\""),
            (r#"{"panel":"x","synth_targets":1,"stream":true}"#, "need a \"window\""),
            (r#"{"panel":"x","synth_targets":1,"window":1}"#, ">= 2"),
            (r#"{"panel":"x","synth_targets":1,"deadline_ms":-4}"#, "non-negative"),
            (r#"{"panel":"x","synth_targets":1,"tenant":7}"#, "string"),
        ];
        for (line, needle) in cases {
            let (_, e) = parse_line(line, 1).expect_err(line);
            assert!(e.contains(needle), "{line} -> {e}");
        }
        // Well-formed variants parse.
        assert!(matches!(parse_line(r#"{"stats":true}"#, 1), Ok((1, Verb::Stats))));
        assert!(matches!(
            parse_line(r#"{"id":4,"shutdown":true}"#, 1),
            Ok((4, Verb::Shutdown))
        ));
        let (_, verb) = parse_line(
            r#"{"panel":"x","synth_targets":1,"window":8,"overlap":2,"tenant":"t","deadline_ms":50}"#,
            1,
        )
        .unwrap();
        match verb {
            Verb::Impute(req) => {
                assert_eq!(req.tenant.as_deref(), Some("t"));
                assert_eq!(req.deadline_ms, Some(50));
                let s = req.stream.unwrap();
                assert_eq!((s.window, s.overlap), (8, 2));
            }
            _ => panic!("expected an impute request"),
        }
    }
}
