//! Newline-delimited JSON frontend — `poets-impute serve`.
//!
//! One request per input line, one response per output line, responses in
//! request order.  No sockets: the transport is stdin/stdout, which makes
//! the service scriptable and CI-testable (`printf ... | poets-impute
//! serve`) in the offline environment; a network listener is a transport
//! wrapper away and deliberately out of scope here.
//!
//! ## Request line
//!
//! ```json
//! {"id": 1, "panel": "synth:hap=8,mark=21,annot=0.2,seed=7",
//!  "engine": "event", "synth_targets": 2, "target_seed": 9}
//! ```
//!
//! * `panel` (string, required) — registry name: a registered panel, a
//!   `synth:hap=..,mark=..` spec, or a file-backed `vcf:<path>` /
//!   `packed:<path>` spec (see [`super::registry`]).  A missing or corrupt
//!   file fails that request in-band (`serve-error/v1`), like any other
//!   bad request — never a worker panic.
//! * `engine` (string, default `"event"`) — any `EngineSpec` spelling.
//! * `targets` (array of arrays) — observation vectors, one per target:
//!   `-1` untyped, `0`/`1` typed alleles.  Mutually exclusive with:
//! * `synth_targets` (int) + `target_seed` (int, default 0) — mint targets
//!   server-side (testing/load-gen): from the panel's synthetic recipe when
//!   it has one, otherwise Li & Stephens mosaics of the panel itself on a
//!   1-in-10 annotation grid (so file-backed panels work too).  Minting is
//!   **deferred to the worker pool** (`RequestTargets::Mint`): the stream
//!   reader never resolves the panel, so a slow file-backed load can't
//!   head-of-line block admission of later lines; mint failures (bad spec,
//!   over-cap count) come back as in-band `serve-error/v1` lines like any
//!   other per-request failure.
//! * `id` (int, default: 1-based line number) — echoed in the response.
//!
//! ## Response line
//!
//! On success, the `poets-impute/serve-report/v1` document (see
//! [`super::report`]) plus `"id"` and `"ok": true`.  On failure,
//! `{"schema": "poets-impute/serve-error/v1", "id": .., "ok": false,
//! "error": ".."}` — a bad request fails in-band and the stream keeps
//! serving; only transport errors (unreadable input, broken pipe) abort.
//!
//! Responses are emitted in request order, but requests are submitted as
//! they are read — the service coalesces and executes them concurrently,
//! so piping a burst of same-panel lines exercises the real batching path.

use std::collections::VecDeque;
use std::io::{BufRead, Write};

use crate::model::panel::TargetHaplotype;
use crate::session::EngineSpec;
use crate::util::json::Json;

use super::queue::{RequestTargets, Ticket};
use super::{ImputeRequest, ServeReport, Service};

/// What a stream session did (the CLI prints this to stderr at EOF).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamSummary {
    pub requests: u64,
    pub ok: u64,
    pub failed: u64,
}

/// An in-order response slot: answered immediately (parse/admission error)
/// or waiting on a service ticket.
enum Slot {
    Ready(Json),
    InFlight(i64, Ticket),
}

/// Drive the service from `input` to `output` until EOF.  Per-request
/// failures are in-band error lines; only transport failures return `Err`.
pub fn serve_stream<R: BufRead, W: Write>(
    service: &Service,
    input: R,
    mut output: W,
) -> Result<StreamSummary, String> {
    let mut summary = StreamSummary::default();
    let mut slots: VecDeque<Slot> = VecDeque::new();
    let mut line_no = 0i64;

    for line in input.lines() {
        let line = line.map_err(|e| format!("reading request stream: {e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        line_no += 1;
        summary.requests += 1;
        let slot = match parse_request(&line, line_no) {
            Ok((id, req)) => loop {
                match service.submit(req.clone()) {
                    Ok(ticket) => break Slot::InFlight(id, ticket),
                    // Backpressure, not failure: this reader is the only
                    // submitter of these slots, so when the queue is full we
                    // block on our own head-of-line response (freeing queue
                    // space) and resubmit, instead of failing requests a
                    // blocking pipe was happy to wait for.
                    Err(e) if e.starts_with("admission: queue full") && !slots.is_empty() => {
                        if let Some(json) = pop_ready(&mut slots, &mut summary, true) {
                            write_line(&mut output, &json)?;
                        }
                    }
                    Err(e) => break Slot::Ready(error_response(id, &e, &mut summary)),
                }
            },
            Err((id, e)) => Slot::Ready(error_response(id, &e, &mut summary)),
        };
        slots.push_back(slot);
        // Opportunistically flush responses that are already done, in
        // order, so a long-lived pipe sees answers without waiting for EOF.
        while let Some(json) = pop_ready(&mut slots, &mut summary, false) {
            write_line(&mut output, &json)?;
        }
    }
    // EOF: block for everything still in flight.
    while let Some(json) = pop_ready(&mut slots, &mut summary, true) {
        write_line(&mut output, &json)?;
    }
    Ok(summary)
}

/// Pop the head slot if it has (or, when `block`, once it gets) an answer.
fn pop_ready(
    slots: &mut VecDeque<Slot>,
    summary: &mut StreamSummary,
    block: bool,
) -> Option<Json> {
    let ready = match slots.front() {
        None => return None,
        Some(Slot::Ready(_)) => true,
        Some(Slot::InFlight(..)) => block,
    };
    if !ready {
        // Head still in flight and we may not block: peek without consuming.
        if let Some(Slot::InFlight(id, ticket)) = slots.front() {
            let result = ticket.try_wait()?;
            let json = result_response(*id, result, summary);
            slots.pop_front();
            return Some(json);
        }
        return None;
    }
    match slots.pop_front()? {
        Slot::Ready(json) => Some(json),
        Slot::InFlight(id, ticket) => Some(result_response(id, ticket.wait(), summary)),
    }
}

fn write_line<W: Write>(output: &mut W, json: &Json) -> Result<(), String> {
    writeln!(output, "{}", json.render()).map_err(|e| format!("writing response: {e}"))?;
    output
        .flush()
        .map_err(|e| format!("flushing response: {e}"))
}

fn result_response(
    id: i64,
    result: Result<ServeReport, String>,
    summary: &mut StreamSummary,
) -> Json {
    match result {
        Ok(report) => {
            summary.ok += 1;
            let mut j = report.to_json();
            j.set("id", id).set("ok", true);
            j
        }
        Err(e) => error_response(id, &e, summary),
    }
}

fn error_response(id: i64, error: &str, summary: &mut StreamSummary) -> Json {
    summary.failed += 1;
    let mut j = Json::obj();
    j.set("schema", "poets-impute/serve-error/v1")
        .set("id", id)
        .set("ok", false)
        .set("error", error);
    j
}

const KNOWN_KEYS: [&str; 6] = [
    "id",
    "panel",
    "engine",
    "targets",
    "synth_targets",
    "target_seed",
];

/// Parse one request line.  Errors carry the best-known request id so the
/// error response still correlates with the input line.  Parsing never
/// touches the panel registry: `synth_targets` becomes a deferred
/// [`RequestTargets::Mint`] executed in the worker pool.
fn parse_request(line: &str, line_no: i64) -> Result<(i64, ImputeRequest), (i64, String)> {
    let j = Json::parse(line).map_err(|e| (line_no, format!("bad request JSON: {e}")))?;
    // Client ids are echoed verbatim (negative ids included), so they stay
    // i64 end to end instead of wrapping through a u64 cast.
    let id = j.get("id").and_then(Json::as_i64).unwrap_or(line_no);
    let fail = |e: String| (id, e);

    if let Json::Obj(pairs) = &j {
        for (key, _) in pairs {
            if !KNOWN_KEYS.contains(&key.as_str()) {
                return Err(fail(format!(
                    "unknown request key {key:?} (expected one of {KNOWN_KEYS:?})"
                )));
            }
        }
    } else {
        return Err(fail("request line must be a JSON object".into()));
    }

    let panel = j
        .get("panel")
        .and_then(Json::as_str)
        .ok_or_else(|| fail("request needs a \"panel\" string".into()))?
        .to_string();
    let engine: EngineSpec = j
        .get("engine")
        .and_then(Json::as_str)
        .unwrap_or("event")
        .parse()
        .map_err(fail)?;

    let targets = match (j.get("targets"), j.get("synth_targets")) {
        (Some(_), Some(_)) => {
            return Err(fail(
                "\"targets\" and \"synth_targets\" are mutually exclusive".into(),
            ));
        }
        (Some(t), None) => RequestTargets::Explicit(parse_targets(t).map_err(fail)?),
        (None, Some(n)) => {
            let count = n
                .as_usize()
                .ok_or_else(|| fail("\"synth_targets\" must be a non-negative int".into()))?;
            let seed = j
                .get("target_seed")
                .and_then(Json::as_i64)
                .unwrap_or(0) as u64;
            RequestTargets::Mint { count, seed }
        }
        (None, None) => {
            return Err(fail(
                "request needs \"targets\" or \"synth_targets\"".into(),
            ));
        }
    };

    Ok((id, ImputeRequest {
        panel,
        engine,
        targets,
    }))
}

/// Observation vectors: arrays of `-1 | 0 | 1`, one per target.
fn parse_targets(j: &Json) -> Result<Vec<TargetHaplotype>, String> {
    let rows = j
        .as_arr()
        .ok_or("\"targets\" must be an array of observation arrays")?;
    let mut targets = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let obs_row = row
            .as_arr()
            .ok_or_else(|| format!("target {i} must be an array of -1|0|1"))?;
        let mut obs = Vec::with_capacity(obs_row.len());
        for v in obs_row {
            let o = v
                .as_i64()
                .filter(|o| (-1..=1).contains(o))
                .ok_or_else(|| format!("target {i}: observations must be -1|0|1"))?;
            obs.push(o as i8);
        }
        targets.push(TargetHaplotype::new(obs));
    }
    Ok(targets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{PanelRegistry, ServeConfig};
    use std::sync::Arc;

    const PANEL: &str = "synth:hap=8,mark=21,annot=0.2,seed=7";

    fn run(input: &str) -> (StreamSummary, Vec<Json>) {
        let service = Service::start(Arc::new(PanelRegistry::new()), ServeConfig::default());
        let mut out = Vec::new();
        let summary = serve_stream(&service, input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines = text
            .lines()
            .map(|l| Json::parse(l).expect("every response line is valid JSON"))
            .collect();
        (summary, lines)
    }

    #[test]
    fn three_requests_three_wellformed_responses() {
        let l1 = format!(r#"{{"id":1,"panel":"{PANEL}","engine":"baseline","synth_targets":2}}"#);
        let l2 = format!(
            r#"{{"id":2,"panel":"{PANEL}","engine":"rank1","synth_targets":1,"target_seed":3}}"#
        );
        let l3 = format!(r#"{{"id":3,"panel":"{PANEL}","engine":"event","synth_targets":1}}"#);
        let input = format!("{l1}\n{l2}\n{l3}\n");
        let (summary, lines) = run(&input);
        assert_eq!(summary.requests, 3);
        assert_eq!(summary.ok, 3);
        assert_eq!(summary.failed, 0);
        assert_eq!(lines.len(), 3);
        for (i, j) in lines.iter().enumerate() {
            assert_eq!(
                j.get("schema").unwrap().as_str(),
                Some("poets-impute/serve-report/v1")
            );
            assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
            assert_eq!(j.get("id").unwrap().as_i64(), Some(i as i64 + 1));
            assert!(!j.get("dosages").unwrap().as_arr().unwrap().is_empty());
        }
        // Responses preserve request order.
        assert_eq!(lines[0].get("engine").unwrap().as_str(), Some("baseline"));
        assert_eq!(lines[2].get("engine").unwrap().as_str(), Some("event"));
    }

    #[test]
    fn explicit_targets_and_blank_lines() {
        let obs: Vec<String> = (0..21)
            .map(|m| (if m % 5 == 0 { "1" } else { "-1" }).to_string())
            .collect();
        let input = format!(
            "\n{{\"panel\":\"{PANEL}\",\"engine\":\"baseline\",\"targets\":[[{}]]}}\n\n",
            obs.join(",")
        );
        let (summary, lines) = run(&input);
        assert_eq!(summary.requests, 1);
        assert_eq!(summary.ok, 1);
        assert_eq!(lines.len(), 1);
        // Default id = 1-based request number.
        assert_eq!(lines[0].get("id").unwrap().as_i64(), Some(1));
        let d = lines[0].get("dosages").unwrap().as_arr().unwrap();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].as_arr().unwrap().len(), 21);
    }

    #[test]
    fn bad_lines_fail_in_band_and_the_stream_continues() {
        let input = format!(
            "not json at all\n\
             {{\"id\":7,\"panel\":\"{PANEL}\",\"engine\":\"warp\",\"synth_targets\":1}}\n\
             {{\"id\":8,\"panel\":\"{PANEL}\",\"bogus\":1,\"synth_targets\":1}}\n\
             {{\"id\":9,\"panel\":\"{PANEL}\",\"synth_targets\":1}}\n"
        );
        let (summary, lines) = run(&input);
        assert_eq!(summary.requests, 4);
        assert_eq!(summary.ok, 1);
        assert_eq!(summary.failed, 3);
        assert_eq!(lines.len(), 4);
        for j in &lines[..3] {
            assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
            assert_eq!(
                j.get("schema").unwrap().as_str(),
                Some("poets-impute/serve-error/v1")
            );
            assert!(j.get("error").unwrap().as_str().is_some());
        }
        assert_eq!(lines[1].get("id").unwrap().as_i64(), Some(7));
        assert_eq!(lines[2].get("id").unwrap().as_i64(), Some(8));
        assert_eq!(lines[3].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(lines[3].get("id").unwrap().as_i64(), Some(9));
    }

    #[test]
    fn backpressure_blocks_instead_of_shedding_for_pipes() {
        // Capacity 1, one worker, eight lines: the reader must throttle on
        // its own in-flight responses, so a blocking pipe never sees
        // spurious "queue full" failures.
        let service = Service::start(
            Arc::new(PanelRegistry::new()),
            ServeConfig::default().workers(1).queue_capacity(1),
        );
        let mut input = String::new();
        for i in 0..8 {
            input.push_str(&format!(
                r#"{{"id":{i},"panel":"{PANEL}","engine":"rank1","synth_targets":1}}"#
            ));
            input.push('\n');
        }
        let mut out = Vec::new();
        let summary = serve_stream(&service, input.as_bytes(), &mut out).unwrap();
        assert_eq!(summary.requests, 8);
        assert_eq!(summary.ok, 8, "queue-full must backpressure, not shed");
        assert_eq!(summary.failed, 0);
    }

    #[test]
    fn negative_ids_echo_verbatim() {
        let input = format!(r#"{{"id":-3,"panel":"{PANEL}","engine":"rank1","synth_targets":1}}"#)
            + "\n";
        let (summary, lines) = run(&input);
        assert_eq!(summary.ok, 1);
        assert_eq!(lines[0].get("id").unwrap().as_i64(), Some(-3));
    }

    #[test]
    fn out_of_range_synth_spec_fails_in_band() {
        // A spec that would trip panelgen asserts must come back as an
        // in-band error line, not kill the stream (or a pool worker).
        let input = concat!(
            r#"{"id":1,"panel":"synth:hap=8,mark=21,maf=0.9","synth_targets":1}"#,
            "\n",
            r#"{"id":2,"panel":"synth:hap=8,mark=21,annot=0.2,seed=7","engine":"rank1","synth_targets":1}"#,
            "\n"
        );
        let (summary, lines) = run(input);
        assert_eq!(summary.failed, 1);
        assert_eq!(summary.ok, 1);
        assert!(
            lines[0]
                .get("error")
                .unwrap()
                .as_str()
                .unwrap()
                .contains("synth spec")
        );
        assert_eq!(lines[1].get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn targets_must_be_valid_observations() {
        let input = format!(r#"{{"panel":"{PANEL}","targets":[[0,2,1]]}}"#) + "\n";
        let (summary, lines) = run(&input);
        assert_eq!(summary.failed, 1);
        assert!(
            lines[0]
                .get("error")
                .unwrap()
                .as_str()
                .unwrap()
                .contains("-1|0|1")
        );
    }
}
