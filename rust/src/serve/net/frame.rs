//! Length-framed transport codec.
//!
//! One frame = a big-endian `u32` payload length followed by that many
//! bytes of UTF-8 JSON — the same documents the stdin JSONL frontend
//! exchanges one-per-line, minus the trailing newline (TCP is not
//! line-oriented; the length prefix is the delimiter).  The codec is
//! deliberately tiny and symmetric: clients and the server use the same
//! two functions, and the CLI's `serve --connect` bridge is nothing but
//! `read line → write_frame` / `read_frame → write line`.
//!
//! Malformed input never panics and never kills the listener; per
//! connection it degrades to:
//!
//! * clean EOF on a frame boundary → [`ReadFrame::Eof`] (client done);
//! * a length prefix above [`MAX_FRAME_LEN`] → [`FrameError::Oversize`]
//!   (answered in-band with a `serve-error/v1`, then the connection is
//!   closed — the declared length cannot be trusted as a skip distance);
//! * EOF mid-prefix or mid-payload → [`FrameError::Truncated`] (dropped:
//!   there is no response channel left worth writing to);
//! * any transport error → [`FrameError::Io`].

use std::io::{self, Read, Write};

/// Hard cap on one frame's payload (64 MiB).  Large enough for a
/// chromosome-scale dosage matrix, small enough that a hostile 4 GiB
/// length prefix cannot make a connection thread allocate unboundedly.
pub const MAX_FRAME_LEN: u32 = 64 << 20;

/// One read attempt's outcome (success side).
#[derive(Debug)]
pub enum ReadFrame {
    /// A complete frame's payload.
    Frame(Vec<u8>),
    /// The peer closed cleanly on a frame boundary.
    Eof,
}

/// One read attempt's outcome (failure side).
#[derive(Debug)]
pub enum FrameError {
    /// Declared length exceeds [`MAX_FRAME_LEN`].
    Oversize(u32),
    /// The stream ended inside a length prefix or payload.
    Truncated,
    /// Transport failure.
    Io(io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversize(n) => write!(
                f,
                "frame: declared length {n} exceeds the {MAX_FRAME_LEN}-byte cap"
            ),
            FrameError::Truncated => write!(f, "frame: stream ended mid-frame"),
            FrameError::Io(e) => write!(f, "frame: {e}"),
        }
    }
}

/// Read one length-prefixed frame.  Distinguishes a clean close (EOF
/// before any prefix byte) from a truncated one (EOF after).
pub fn read_frame<R: Read>(r: &mut R) -> Result<ReadFrame, FrameError> {
    let mut prefix = [0u8; 4];
    let mut filled = 0;
    while filled < prefix.len() {
        match r.read(&mut prefix[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Ok(ReadFrame::Eof)
                } else {
                    Err(FrameError::Truncated)
                };
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let len = u32::from_be_bytes(prefix);
    if len > MAX_FRAME_LEN {
        return Err(FrameError::Oversize(len));
    }
    let mut payload = vec![0u8; len as usize];
    match r.read_exact(&mut payload) {
        Ok(()) => Ok(ReadFrame::Frame(payload)),
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => Err(FrameError::Truncated),
        Err(e) => Err(FrameError::Io(e)),
    }
}

/// Write one frame (prefix + payload).  The caller flushes (a writer
/// draining a burst of parts batches its flushes).
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME_LEN as usize);
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(payloads: &[&[u8]]) -> Vec<Vec<u8>> {
        let mut buf = Vec::new();
        for p in payloads {
            write_frame(&mut buf, p).unwrap();
        }
        let mut r = Cursor::new(buf);
        let mut out = Vec::new();
        loop {
            match read_frame(&mut r).unwrap() {
                ReadFrame::Frame(p) => out.push(p),
                ReadFrame::Eof => break,
            }
        }
        out
    }

    #[test]
    fn frames_roundtrip_in_order() {
        let out = roundtrip(&[b"hello", b"", b"{\"id\":1}"]);
        assert_eq!(out, vec![b"hello".to_vec(), Vec::new(), b"{\"id\":1}".to_vec()]);
    }

    #[test]
    fn clean_eof_only_on_frame_boundary() {
        let mut empty = Cursor::new(Vec::<u8>::new());
        assert!(matches!(read_frame(&mut empty).unwrap(), ReadFrame::Eof));

        // EOF inside the prefix.
        let mut mid_prefix = Cursor::new(vec![0u8, 0]);
        assert!(matches!(
            read_frame(&mut mid_prefix).unwrap_err(),
            FrameError::Truncated
        ));

        // EOF inside the payload.
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        buf.truncate(buf.len() - 2);
        let mut mid_payload = Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut mid_payload).unwrap_err(),
            FrameError::Truncated
        ));
    }

    #[test]
    fn oversize_prefix_is_rejected_without_allocating() {
        let mut buf = u32::MAX.to_be_bytes().to_vec();
        buf.extend_from_slice(b"junk");
        let mut r = Cursor::new(buf);
        match read_frame(&mut r).unwrap_err() {
            FrameError::Oversize(n) => assert_eq!(n, u32::MAX),
            other => panic!("expected Oversize, got {other:?}"),
        }
        // Error text names the cap (it is sent in-band to the client).
        let msg = FrameError::Oversize(u32::MAX).to_string();
        assert!(msg.contains("exceeds"), "{msg}");
    }

    #[test]
    fn junk_after_a_valid_frame_surfaces_as_truncation_not_panic() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"{\"ok\":true}").unwrap();
        buf.extend_from_slice(&[0x00, 0x01]); // stray bytes, then EOF
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r).unwrap(), ReadFrame::Frame(_)));
        assert!(matches!(
            read_frame(&mut r).unwrap_err(),
            FrameError::Truncated
        ));
    }
}
