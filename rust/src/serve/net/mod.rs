//! `serve::net` — the socketed serve plane.
//!
//! A TCP transport over the exact same protocol the stdin frontend speaks:
//! each frame ([`frame`]) carries one JSON document — request in, response
//! (or streamed `serve-report-part/v1` sequence) out — byte-identical to
//! the corresponding stdin JSONL line minus the newline.  Parsing and
//! response construction are shared with [`super::jsonl`], so the two
//! frontends cannot drift.
//!
//! ## Concurrency model
//!
//! One accept loop (non-blocking, polling the shutdown flag), two threads
//! per connection:
//!
//! * the **reader** decodes frames, parses verbs, submits to the
//!   [`ShardedService`], and hands tickets to the writer over a *bounded*
//!   channel ([`CONN_BACKLOG`] slots);
//! * the **writer** resolves tickets in request order and writes response
//!   frames (streamed parts as each window completes).
//!
//! The bounded channel is the per-connection backpressure: a client that
//! stops reading stalls its own writer, fills its own channel, and blocks
//! its own reader — it never blocks the accept loop or another
//! connection.  A write timeout ([`WRITE_TIMEOUT`]) eventually reaps
//! connections that are stalled *and* dead.
//!
//! One admission difference from the pipe frontend: stdin's single reader
//! blocks on its own head-of-line response when the service queue fills
//! (a pipe is happy to wait), but a TCP service has many competing
//! submitters, so `admission: queue full` sheds in-band instead — the
//! client sees a typed `serve-error/v1` and may retry.
//!
//! ## Degradation and shutdown
//!
//! Malformed input follows [`frame`]'s taxonomy: an oversize length prefix
//! is answered in-band then the connection closes (the declared length
//! cannot be trusted as a skip distance); a truncated or garbled stream
//! drops that connection silently.  Neither ever panics or stalls the
//! listener.
//!
//! A `{"shutdown": true}` verb from any connection (the SIGTERM-equivalent
//! for the socket transport) is acknowledged with a draining
//! `serve-stats/v1`, then: the accept loop stops, every open connection's
//! read half is shut down (its reader sees EOF and drains in-flight
//! tickets to its client), all handlers are joined, and [`serve_tcp`]
//! returns.  The caller then drains the service itself
//! ([`ShardedService::shutdown`]) — every admitted request completes.

pub mod frame;

use std::collections::{BTreeMap, VecDeque};
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use crate::util::json::Json;

use super::jsonl::{self, StreamSummary, Verb};
use super::queue::Ticket;
use super::ShardedService;

use frame::{read_frame, FrameError, ReadFrame};

/// Per-connection response backlog (tickets + ready documents) before the
/// reader blocks — the slow-client backpressure bound.
pub const CONN_BACKLOG: usize = 64;

/// Give up writing to a client that has stalled this long; the connection
/// is dropped (its admitted requests still complete server-side).
pub const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// What a listener session did, summed over every connection.
#[derive(Clone, Copy, Debug, Default)]
pub struct TcpServeSummary {
    pub connections: u64,
    pub requests: u64,
    pub ok: u64,
    pub failed: u64,
}

/// One in-order response slot travelling reader → writer.
enum ConnItem {
    /// Already answered (parse/admission error, admin verb).
    Ready(Json),
    /// Waiting on the service.
    InFlight(i64, Ticket),
    /// Waiting on the service, emitting parts as windows complete.
    Streaming(i64, Ticket),
}

/// Serve `listener` until a `shutdown` verb arrives on any connection.
/// Per-request and per-connection failures are absorbed (in-band errors or
/// connection drops); only listener-level failures return `Err`.  The
/// caller still owns the service and is expected to drain it afterwards.
pub fn serve_tcp(
    service: &ShardedService,
    listener: TcpListener,
) -> Result<TcpServeSummary, String> {
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("listener: {e}"))?;
    let shutdown = AtomicBool::new(false);
    let conns: Mutex<Vec<TcpStream>> = Mutex::new(Vec::new());
    let mut totals = TcpServeSummary::default();

    thread::scope(|s| -> Result<(), String> {
        let mut handles = Vec::new();
        while !shutdown.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nodelay(true);
                    // Keep a handle on the read half so graceful shutdown
                    // can nudge a blocked reader to EOF.
                    if let Ok(clone) = stream.try_clone() {
                        conns.lock().unwrap().push(clone);
                    }
                    totals.connections += 1;
                    let shutdown = &shutdown;
                    handles.push(s.spawn(move || handle_conn(service, stream, shutdown)));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(format!("accept: {e}")),
            }
        }
        // Drain: stop accepting (done), EOF every open reader, join.
        for c in conns.lock().unwrap().iter() {
            let _ = c.shutdown(Shutdown::Read);
        }
        for h in handles {
            if let Ok(sm) = h.join() {
                totals.requests += sm.requests;
                totals.ok += sm.ok;
                totals.failed += sm.failed;
            }
        }
        Ok(())
    })?;
    Ok(totals)
}

/// One connection's reader: frames → verbs → tickets, in-order handoff to
/// the writer thread.  Returns the connection's combined summary.
fn handle_conn(service: &ShardedService, stream: TcpStream, shutdown: &AtomicBool) -> StreamSummary {
    let Ok(write_half) = stream.try_clone() else {
        return StreamSummary::default();
    };
    let _ = write_half.set_write_timeout(Some(WRITE_TIMEOUT));
    let (tx, rx) = mpsc::sync_channel::<ConnItem>(CONN_BACKLOG);
    let writer = thread::spawn(move || write_conn(write_half, rx));

    let mut reader = BufReader::new(stream);
    let mut requests = 0u64;
    let mut line_no = 0i64;
    loop {
        match read_frame(&mut reader) {
            Ok(ReadFrame::Eof) => break,
            Ok(ReadFrame::Frame(bytes)) => {
                line_no += 1;
                requests += 1;
                let item = match String::from_utf8(bytes) {
                    Err(_) => {
                        ConnItem::Ready(jsonl::error_json(line_no, "request frame is not UTF-8"))
                    }
                    Ok(text) => match jsonl::parse_line(&text, line_no) {
                        Ok((id, Verb::Impute(req))) => match service.submit(*req) {
                            Ok(t) if t.is_streaming() => ConnItem::Streaming(id, t),
                            Ok(t) => ConnItem::InFlight(id, t),
                            Err(e) => ConnItem::Ready(jsonl::error_json(id, &e)),
                        },
                        Ok((id, Verb::Stats)) => {
                            ConnItem::Ready(jsonl::stats_json(id, service, false))
                        }
                        Ok((id, Verb::Shutdown)) => {
                            shutdown.store(true, Ordering::SeqCst);
                            let _ = tx.send(ConnItem::Ready(jsonl::stats_json(id, service, true)));
                            break;
                        }
                        Err((id, e)) => ConnItem::Ready(jsonl::error_json(id, &e)),
                    },
                };
                if tx.send(item).is_err() {
                    break; // writer bailed (client gone)
                }
            }
            Err(FrameError::Oversize(n)) => {
                // Answer in-band, then close: the declared length cannot be
                // trusted as a skip distance, so there is no resync point.
                line_no += 1;
                requests += 1;
                let msg = FrameError::Oversize(n).to_string();
                let _ = tx.send(ConnItem::Ready(jsonl::error_json(line_no, &msg)));
                break;
            }
            Err(_) => break, // truncated / transport error: drop silently
        }
    }
    drop(tx); // writer drains remaining items, then exits
    let mut summary = writer.join().unwrap_or_default();
    summary.requests += requests;
    let _ = reader.get_ref().shutdown(Shutdown::Both);
    summary
}

/// One connection's writer: resolve items in request order, frame out the
/// responses.  On a write failure the client is gone — stop writing and
/// let the reader's next handoff fail (admitted work still completes
/// server-side).
fn write_conn(stream: TcpStream, rx: mpsc::Receiver<ConnItem>) -> StreamSummary {
    let mut w = BufWriter::new(stream);
    let mut summary = StreamSummary::default();
    for item in rx {
        let wrote = match item {
            ConnItem::Ready(json) => {
                match json.get("ok").and_then(Json::as_bool) {
                    Some(true) => summary.ok += 1,
                    _ => summary.failed += 1,
                }
                emit(&mut w, &json)
            }
            ConnItem::InFlight(id, ticket) => {
                let json = jsonl::result_json(id, ticket.wait(), &mut summary);
                emit(&mut w, &json)
            }
            ConnItem::Streaming(id, ticket) => (|| {
                let mut parts = 0usize;
                while let Some(part) = ticket.recv_part() {
                    emit(&mut w, &jsonl::part_json(id, &part))?;
                    parts += 1;
                }
                let json = jsonl::stream_final_json(id, ticket.wait(), parts, &mut summary);
                emit(&mut w, &json)
            })(),
        };
        if wrote.is_err() {
            break;
        }
    }
    summary
}

/// Frame + flush one document (each streamed part flushes: the client
/// should see windows as they complete, not at connection EOF).
fn emit(w: &mut BufWriter<TcpStream>, json: &Json) -> io::Result<()> {
    frame::write_frame(w, json.render().as_bytes())?;
    w.flush()
}

/// Reconnect schedule for [`bridge_jsonl`]: up to [`RECONNECT_ATTEMPTS`]
/// consecutive failed connects, sleeping `RECONNECT_BASE_MS << (attempt-1)`
/// milliseconds between them, capped at [`RECONNECT_CAP_MS`].
pub const RECONNECT_ATTEMPTS: u32 = 5;
pub const RECONNECT_BASE_MS: u64 = 100;
pub const RECONNECT_CAP_MS: u64 = 1_600;

/// What one [`bridge_jsonl`] session did.
#[derive(Clone, Copy, Debug, Default)]
pub struct BridgeSummary {
    /// Response documents written to the output (streamed parts included).
    pub responses: u64,
    /// Connections re-established after the first one died.
    pub reconnects: u64,
}

/// Book-keeping shared between the bridge's input pump and its per-
/// connection uplink threads.
#[derive(Default)]
struct BridgeState {
    /// Input lines not yet written to the live connection.
    queue: VecDeque<(Option<i64>, String)>,
    /// Sent requests still awaiting a *terminal* response, by id (streamed
    /// parts don't settle a request; its manifest does).
    unanswered: BTreeMap<i64, String>,
    /// The input side reached EOF (no more lines will arrive).
    input_eof: bool,
    /// Bumped per (re)connection; a stale uplink sees the mismatch and exits.
    generation: u64,
    /// A failed input read, reported after the in-flight work drains.
    pump_err: Option<String>,
}

/// The fault-tolerant `serve --connect` bridge: JSONL lines from `input`
/// become request frames on a TCP connection to `addr`; response frames
/// become output lines.  When the connection dies mid-stream the bridge
/// reconnects under the capped exponential backoff above and resubmits
/// **only the unanswered requests** (tracked by their `"id"`, in id order)
/// — requests whose terminal response was already delivered are never
/// re-executed.  Delivery is therefore at-least-once across outages: a
/// request the server finished but whose response died on the wire runs
/// again.  Lines without a parsable `"id"` cannot be matched to responses
/// and are sent exactly once.  The initial connect still fails fast — the
/// backoff only covers connections that were lost after being established.
pub fn bridge_jsonl<R>(input: R, out: &mut dyn Write, addr: &str) -> Result<BridgeSummary, String>
where
    R: io::BufRead + Send + 'static,
{
    let shared = Arc::new((Mutex::new(BridgeState::default()), Condvar::new()));
    let pump = {
        let shared = Arc::clone(&shared);
        thread::spawn(move || {
            let (lock, cv) = &*shared;
            for line in input.lines() {
                match line {
                    Ok(l) => {
                        if l.trim().is_empty() {
                            continue;
                        }
                        let id = Json::parse(&l)
                            .ok()
                            .and_then(|j| j.get("id").and_then(Json::as_i64));
                        let mut st = lock.lock().unwrap();
                        st.queue.push_back((id, l));
                        cv.notify_all();
                    }
                    Err(e) => {
                        lock.lock().unwrap().pump_err = Some(format!("bridge: input: {e}"));
                        break;
                    }
                }
            }
            let mut st = lock.lock().unwrap();
            st.input_eof = true;
            cv.notify_all();
        })
    };

    let (lock, cv) = &*shared;
    let mut summary = BridgeSummary::default();
    let mut attempt = 0u32;
    let mut connected_before = false;
    loop {
        let conn = match TcpStream::connect(addr) {
            Ok(c) => c,
            Err(e) if !connected_before => {
                return Err(format!("serve: cannot connect to {addr}: {e}"));
            }
            Err(e) => {
                attempt += 1;
                if attempt > RECONNECT_ATTEMPTS {
                    return Err(format!(
                        "serve: lost connection to {addr} and reconnects exhausted: {e}"
                    ));
                }
                let delay = RECONNECT_BASE_MS
                    .saturating_mul(1 << (attempt - 1))
                    .min(RECONNECT_CAP_MS);
                thread::sleep(Duration::from_millis(delay));
                continue;
            }
        };
        if connected_before {
            summary.reconnects += 1;
        }
        connected_before = true;
        attempt = 0;
        let _ = conn.set_nodelay(true);
        let Ok(mut up) = conn.try_clone() else {
            return Err("serve: clone socket".into());
        };

        // Claim this connection's generation (waking, and thereby retiring,
        // any uplink still parked on the previous one).
        let my_gen = {
            let mut st = lock.lock().unwrap();
            st.generation += 1;
            cv.notify_all();
            st.generation
        };

        // Resubmit everything sent-but-unanswered on the previous
        // connection, oldest id first, before any new traffic.
        let resend: Vec<String> = lock.lock().unwrap().unanswered.values().cloned().collect();
        let mut alive = true;
        for line in &resend {
            if frame::write_frame(&mut up, line.as_bytes()).is_err() {
                alive = false;
                break;
            }
        }
        if !alive {
            continue;
        }

        let uplink = {
            let shared = Arc::clone(&shared);
            thread::spawn(move || {
                let (lock, cv) = &*shared;
                loop {
                    let mut st = lock.lock().unwrap();
                    while st.generation == my_gen && st.queue.is_empty() && !st.input_eof {
                        st = cv.wait(st).unwrap();
                    }
                    if st.generation != my_gen {
                        return;
                    }
                    match st.queue.pop_front() {
                        Some((id, line)) => {
                            // Tracked BEFORE the write: a send that fails (or
                            // lands on a half-dead socket) is replayed from
                            // `unanswered` after the reconnect.
                            let tracked = id.is_some();
                            if let Some(id) = id {
                                st.unanswered.insert(id, line.clone());
                            }
                            drop(st);
                            if frame::write_frame(&mut up, line.as_bytes()).is_err() {
                                if !tracked {
                                    lock.lock().unwrap().queue.push_front((None, line));
                                }
                                return;
                            }
                        }
                        None => {
                            // Input EOF with an empty queue: half-close so the
                            // server drains in-flight answers, then closes.
                            let _ = up.shutdown(Shutdown::Write);
                            return;
                        }
                    }
                }
            })
        };

        let mut reader = BufReader::new(conn);
        loop {
            match read_frame(&mut reader) {
                Ok(ReadFrame::Frame(payload)) => {
                    let Ok(text) = String::from_utf8(payload) else {
                        return Err("serve: server sent a non-UTF-8 frame".into());
                    };
                    writeln!(out, "{text}").map_err(|e| format!("serve: output: {e}"))?;
                    out.flush().map_err(|e| format!("serve: output: {e}"))?;
                    summary.responses += 1;
                    if let Ok(j) = Json::parse(&text) {
                        let part = j.get("schema").and_then(Json::as_str)
                            == Some("poets-impute/serve-report-part/v1");
                        if !part {
                            if let Some(id) = j.get("id").and_then(Json::as_i64) {
                                lock.lock().unwrap().unanswered.remove(&id);
                            }
                        }
                    }
                }
                Ok(ReadFrame::Eof) => break,
                Err(_) => break,
            }
        }

        // Nudge an uplink blocked on the dead socket, retire it, and decide
        // whether this close was the orderly end or an outage.
        let _ = reader.get_ref().shutdown(Shutdown::Both);
        {
            let mut st = lock.lock().unwrap();
            st.generation += 1;
            cv.notify_all();
        }
        let _ = uplink.join();
        let (done, pending) = {
            let st = lock.lock().unwrap();
            (
                st.input_eof && st.queue.is_empty() && st.unanswered.is_empty(),
                st.unanswered.len(),
            )
        };
        if done {
            break;
        }
        eprintln!("serve: connection to {addr} lost ({pending} unanswered); reconnecting");
    }
    let _ = pump.join();
    if let Some(e) = lock.lock().unwrap().pump_err.take() {
        return Err(e);
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{PanelRegistry, ServeConfig};
    use std::net::SocketAddr;
    use std::sync::Arc;

    const PANEL: &str = "synth:hap=8,mark=21,annot=0.2,seed=7";

    fn spawn_server(
        cfg: ServeConfig,
        shards: usize,
    ) -> (
        Arc<ShardedService>,
        SocketAddr,
        thread::JoinHandle<Result<TcpServeSummary, String>>,
    ) {
        let svc = Arc::new(ShardedService::start(
            Arc::new(PanelRegistry::new()),
            cfg,
            shards,
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = Arc::clone(&svc);
        let handle = thread::spawn(move || serve_tcp(&server, listener));
        (svc, addr, handle)
    }

    /// Write each line as a frame, half-close, read every response frame.
    fn send_lines(addr: SocketAddr, lines: &[String]) -> Vec<Json> {
        let mut conn = TcpStream::connect(addr).unwrap();
        for l in lines {
            frame::write_frame(&mut conn, l.as_bytes()).unwrap();
        }
        conn.shutdown(Shutdown::Write).unwrap();
        read_all(conn)
    }

    fn read_all(conn: TcpStream) -> Vec<Json> {
        let mut r = BufReader::new(conn);
        let mut out = Vec::new();
        loop {
            match read_frame(&mut r) {
                Ok(ReadFrame::Frame(p)) => {
                    out.push(Json::parse(std::str::from_utf8(&p).unwrap()).unwrap())
                }
                Ok(ReadFrame::Eof) => return out,
                Err(e) => panic!("client read: {e}"),
            }
        }
    }

    fn shut_down(
        addr: SocketAddr,
        handle: thread::JoinHandle<Result<TcpServeSummary, String>>,
    ) -> TcpServeSummary {
        let ack = send_lines(addr, &[r#"{"shutdown":true}"#.to_string()]);
        assert_eq!(ack.len(), 1);
        assert_eq!(ack[0].get("draining").unwrap().as_bool(), Some(true));
        handle.join().unwrap().unwrap()
    }

    #[test]
    fn tcp_roundtrip_serves_requests_in_order() {
        let (svc, addr, handle) = spawn_server(ServeConfig::default(), 2);
        let lines: Vec<String> = [("baseline", 1), ("rank1", 2), ("event", 3)]
            .iter()
            .map(|(eng, id)| {
                format!(r#"{{"id":{id},"panel":"{PANEL}","engine":"{eng}","synth_targets":1}}"#)
            })
            .collect();
        let out = send_lines(addr, &lines);
        assert_eq!(out.len(), 3);
        for (i, j) in out.iter().enumerate() {
            assert_eq!(
                j.get("schema").unwrap().as_str(),
                Some("poets-impute/serve-report/v1")
            );
            assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
            assert_eq!(j.get("id").unwrap().as_i64(), Some(i as i64 + 1));
        }
        assert_eq!(out[0].get("engine").unwrap().as_str(), Some("baseline"));

        // The stats verb works over TCP too.
        let stats = send_lines(addr, &[r#"{"id":9,"stats":true}"#.to_string()]);
        assert_eq!(
            stats[0].get("schema").unwrap().as_str(),
            Some("poets-impute/serve-stats/v1")
        );
        assert_eq!(
            stats[0].get("totals").unwrap().get("completed").unwrap().as_i64(),
            Some(3)
        );

        let summary = shut_down(addr, handle);
        assert_eq!(summary.connections, 3);
        assert_eq!(summary.ok, 5); // 3 reports + stats + shutdown ack
        assert_eq!(summary.failed, 0);
        let stats = Arc::try_unwrap(svc).ok().unwrap().shutdown();
        assert_eq!(stats.completed, 3);
    }

    #[test]
    fn malformed_frames_degrade_per_connection_not_per_listener() {
        let (svc, addr, handle) = spawn_server(ServeConfig::default(), 1);

        // Oversize length prefix: one in-band error frame, then close.
        let mut conn = TcpStream::connect(addr).unwrap();
        use std::io::Write as _;
        conn.write_all(&u32::MAX.to_be_bytes()).unwrap();
        let out = read_all(conn);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get("ok").unwrap().as_bool(), Some(false));
        assert!(
            out[0]
                .get("error")
                .unwrap()
                .as_str()
                .unwrap()
                .contains("exceeds")
        );

        // Truncated frame: silent drop, no response.
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(&[0u8, 0]).unwrap();
        conn.shutdown(Shutdown::Write).unwrap();
        assert!(read_all(conn).is_empty());

        // Junk after a valid frame: the valid request is answered, then the
        // connection drops at the junk.
        let mut conn = TcpStream::connect(addr).unwrap();
        let good = format!(r#"{{"id":4,"panel":"{PANEL}","engine":"rank1","synth_targets":1}}"#);
        frame::write_frame(&mut conn, good.as_bytes()).unwrap();
        conn.write_all(&[0x00, 0x01, 0x02]).unwrap();
        conn.shutdown(Shutdown::Write).unwrap();
        let out = read_all(conn);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(out[0].get("id").unwrap().as_i64(), Some(4));

        // Not-UTF-8 and not-JSON payloads: in-band errors, stream continues.
        let out = send_lines(addr, &["not json".to_string(), good.clone()]);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(out[1].get("ok").unwrap().as_bool(), Some(true));

        // The listener survived all of it.
        let summary = shut_down(addr, handle);
        assert_eq!(summary.connections, 5);
        let stats = Arc::try_unwrap(svc).ok().unwrap().shutdown();
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.failed, 0);
    }

    #[test]
    fn streamed_request_arrives_as_parts_then_manifest() {
        let (svc, addr, handle) = spawn_server(ServeConfig::default(), 1);
        let panel = "synth:hap=8,mark=41,annot=0.2,seed=23";
        let line = format!(
            r#"{{"id":6,"panel":"{panel}","engine":"rank1","synth_targets":1,"window":16,"overlap":4}}"#
        );
        let out = send_lines(addr, &[line]);
        assert!(out.len() >= 3, "parts + manifest, got {}", out.len());
        let (manifest, parts) = out.split_last().unwrap();
        let covered: usize = parts
            .iter()
            .map(|p| {
                assert_eq!(
                    p.get("schema").unwrap().as_str(),
                    Some("poets-impute/serve-report-part/v1")
                );
                p.get("core_end").unwrap().as_usize().unwrap()
                    - p.get("core_start").unwrap().as_usize().unwrap()
            })
            .sum();
        assert_eq!(covered, 41);
        assert_eq!(manifest.get("parts").unwrap().as_usize(), Some(parts.len()));
        assert!(manifest.get("dosages").is_none());

        let summary = shut_down(addr, handle);
        assert_eq!(summary.ok, 2);
        let stats = Arc::try_unwrap(svc).ok().unwrap().shutdown();
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn graceful_shutdown_drains_a_connection_that_stays_open() {
        let (svc, addr, handle) = spawn_server(ServeConfig::default().workers(1), 1);

        // Client A submits and reads its response but keeps the connection
        // open (no half-close).
        let mut a = TcpStream::connect(addr).unwrap();
        let line = format!(r#"{{"id":1,"panel":"{PANEL}","engine":"rank1","synth_targets":1}}"#);
        frame::write_frame(&mut a, line.as_bytes()).unwrap();
        let mut a_reader = BufReader::new(a.try_clone().unwrap());
        let first = match read_frame(&mut a_reader).unwrap() {
            ReadFrame::Frame(p) => Json::parse(std::str::from_utf8(&p).unwrap()).unwrap(),
            ReadFrame::Eof => panic!("expected a response before shutdown"),
        };
        assert_eq!(first.get("ok").unwrap().as_bool(), Some(true));

        // Client B triggers shutdown; the listener must EOF client A's
        // reader, drain, and exit — A sees a clean EOF, not a hang.
        let summary = shut_down(addr, handle);
        assert!(matches!(read_frame(&mut a_reader).unwrap(), ReadFrame::Eof));
        assert_eq!(summary.connections, 2);
        assert_eq!(summary.ok, 2);

        // Every admitted request completed — nothing leaked.
        let stats = Arc::try_unwrap(svc).ok().unwrap().shutdown();
        assert_eq!(stats.accepted, 1);
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.failed, 0);
    }
}
