//! `serve` — a multi-tenant imputation service over the session pipeline.
//!
//! The paper's wall-clock wins only matter downstream if the engine can be
//! *served*: many independent clients, many panels, heavy concurrent
//! traffic.  This subsystem is that serving layer, std-only like the rest of
//! the offline build:
//!
//! * [`PanelRegistry`] — named reference panels loaded once and shared via
//!   `Arc`; every request against the same panel reuses one in-memory copy.
//!   Resolves `synth:` recipes and file-backed `vcf:`/`packed:` specs, with
//!   a bounded least-recently-resolved spec cache (pinned registrations
//!   exempt).
//! * [`ImputeRequest`] / [`Ticket`] — the tenant-facing request/response
//!   pair.  Admission control is a bounded queue: past the configured
//!   capacity ([`ServeConfig`]) pending requests, submits are rejected with
//!   an `admission:` error instead of growing latency without bound.
//! * The **coalescer** ([`CoalescePolicy`]) — concurrently submitted
//!   requests for the same (panel, engine) pair merge into one engine batch
//!   group, bounded by a target budget and an optional linger window (the
//!   budget charges each request's *declared* width — explicit target count
//!   or deferred mint width — see [`RequestTargets`]).  Within a group the
//!   engine is built once and bound once (per request instead when its
//!   `prepare` validates targets, as the interp plane's grid check does).
//!   On the **event plane**, a multi-request group merges every member's
//!   targets into **one wave sweep** (`EventEngine::run` services the whole
//!   batch as a single lane group) and scatters the dosage rows back per
//!   request; because the wave-batched vertices reduce in canonical sender
//!   order, per-target numerics are batch-width invariant and every
//!   response stays **bit-identical** to a standalone
//!   [`ImputeSession`](crate::session::ImputeSession) run
//!   (`tests/serve_roundtrip.rs`).  The other planes keep executing each
//!   member as its own [`TargetBatch`] — same bit-exactness argument,
//!   amortising only engine construction/binding.
//! * The **worker pool** — `ServeConfig::workers` OS threads (the same
//!   std::thread fan-out style as the DES delivery engine), each owning one
//!   [`Engine`] per (panel, engine-spec) pair it has served.  Engine panics
//!   are caught and reported as per-request errors; a failing engine is
//!   dropped from the cache rather than reused.
//! * [`ServeReport`] — the per-request manifest, schema
//!   `poets-impute/serve-report/v1` (the impute-report manifest plus
//!   queue-wait / coalesce-width / batch-id fields and the dosages; see
//!   [`report`]).
//! * **Observability** — requests opting into `"spans": true` get a
//!   [`RequestSpan`] phase timeline (admitted → dequeued → minted →
//!   prepared → run → responded, µs offsets) in their response's
//!   `serve.spans` object, and `serve-stats/v1` carries per-shard
//!   engine-cache hit/miss/eviction counters plus log2-µs queue-wait /
//!   service-time histograms (bucket layout: [`crate::obs`]).
//!
//! Admission is layered (see [`queue`]): a bounded queue (`admission:`
//! errors), optional per-tenant token-bucket quotas ([`TenantQuota`],
//! `quota:` errors) and deadline-aware shedding (`deadline_ms` requests are
//! refused up front when the queue-age estimate from recent service times
//! already busts the budget, and re-checked worker-side against the
//! request's true age — queue wait *plus* deferred-mint time).  Requests
//! may also opt into **windowed streaming** ([`StreamSpec`]): the worker
//! runs the request window-by-window and pushes [`ServePart`] dosage chunks
//! as each window's core span completes, with the final report still
//! carrying the full stitched (bit-identical) dosage matrix.
//!
//! Frontends: this library API, `poets-impute serve` (newline-delimited
//! JSON over stdin/stdout, [`jsonl`]; the same framing over TCP via
//! [`net`]), the panel-sharded [`ShardedService`] ([`shard`]), and two load
//! generators ([`bench`]): the closed-loop sweep behind `BENCH_serve.json`
//! and the Poisson open-loop sweep behind `BENCH_serve_load.json`, cross-
//! checked against the [`mmc`] M/M/c analytic model.
//!
//! ```
//! use std::sync::Arc;
//! use poets_impute::serve::{ImputeRequest, PanelRegistry, ServeConfig, Service};
//! use poets_impute::session::EngineSpec;
//!
//! let registry = Arc::new(PanelRegistry::new());
//! let panel = registry.resolve("synth:hap=8,mark=21,annot=0.2,seed=1").unwrap();
//! let targets = panel.synthetic_targets(2, 7).unwrap();
//!
//! let service = Service::start(Arc::clone(&registry), ServeConfig::default().workers(2));
//! let report = service
//!     .submit(ImputeRequest::new(panel.name(), EngineSpec::Rank1, targets))
//!     .unwrap()
//!     .wait()
//!     .unwrap();
//! assert_eq!(report.dosages().len(), 2);
//! let stats = service.shutdown();
//! assert_eq!(stats.completed, 1);
//! ```

pub mod bench;
pub mod jsonl;
pub mod mmc;
pub mod net;
pub mod queue;
pub mod registry;
pub mod report;
pub mod shard;

pub use queue::{
    CoalescePolicy, ImputeRequest, RequestSpan, RequestTargets, ServePart, ServiceStats,
    StreamSpec, TenantQuota, Ticket,
};
pub use registry::{PanelRegistry, RegisteredPanel};
pub use report::ServeReport;
pub use shard::ShardedService;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, mpsc};
use std::thread;
use std::time::Instant;

use crate::graph::mapping::MappingStrategy;
use crate::imputation::app::RawAppConfig;
use crate::model::panel::TargetHaplotype;
use crate::poets::topology::ClusterConfig;
use crate::session::{
    Engine, EngineOutput, EngineSpec, ImputeReport, TargetBatch, Workload, build_engine,
};

use queue::{Pending, QueueState};

const POISONED: &str = "serve queue lock poisoned";

/// Service shape: pool size, coalescing policy, admission bound and the
/// engine knobs every request runs under (one service = one engine
/// configuration; run several services for A/B configurations).
#[derive(Clone)]
pub struct ServeConfig {
    /// Worker threads servicing coalesced batches.
    pub workers: usize,
    /// Request-merging policy ([`CoalescePolicy::off`] disables).
    pub coalesce: CoalescePolicy,
    /// Max requests waiting in the queue before submits are rejected.
    pub queue_capacity: usize,
    /// Engine configuration (cluster shape, model params, soft-scheduling,
    /// DES host threads) shared by every request.
    pub app: RawAppConfig,
    /// Vertex→thread mapping strategy for the event planes.
    pub mapping: MappingStrategy,
    /// Optional per-tenant token-bucket quota.  Applies to every request
    /// naming a `tenant`; `None` disables quota shedding entirely.
    pub quota: Option<TenantQuota>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            coalesce: CoalescePolicy::default(),
            queue_capacity: 1024,
            quota: None,
            app: RawAppConfig {
                cluster: ClusterConfig::with_boards(2),
                states_per_thread: 8,
                ..RawAppConfig::default()
            },
            mapping: MappingStrategy::Manual2d,
        }
    }
}

impl ServeConfig {
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    pub fn coalesce(mut self, policy: CoalescePolicy) -> Self {
        self.coalesce = policy;
        self
    }

    /// Disable request merging (every request runs alone).
    pub fn no_coalesce(self) -> Self {
        self.coalesce(CoalescePolicy::off())
    }

    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.queue_capacity = n.max(1);
        self
    }

    /// Enable per-tenant token-bucket quotas: `rate_per_s` sustained
    /// requests/s with a `burst`-token bucket per tenant name.
    pub fn tenant_quota(mut self, rate_per_s: f64, burst: f64) -> Self {
        self.quota = Some(TenantQuota::new(rate_per_s, burst));
        self
    }

    /// Simulated cluster size for the event planes.
    pub fn boards(mut self, n: usize) -> Self {
        self.app.cluster = ClusterConfig::with_boards(n);
        self
    }

    /// Soft-scheduling factor (panel states per hardware thread).
    pub fn states_per_thread(mut self, n: usize) -> Self {
        self.app.states_per_thread = n.max(1);
        self
    }

    /// Host worker threads for the DES deliver/step phases *inside* one
    /// engine run (orthogonal to the service worker pool).
    pub fn threads(mut self, n: usize) -> Self {
        self.app.sim.threads = Some(n.max(1));
        self
    }

    /// Serve against a full [`ScenarioSpec`](crate::poets::scenario): the
    /// cluster shape comes from the spec, and its fault schedule (tile
    /// failures, lossy links) rides along into every event-plane run.
    /// Recovery telemetry feeds the degraded-service admission path.
    pub fn scenario(mut self, spec: crate::poets::scenario::ScenarioSpec) -> Self {
        self.app.cluster = spec.cluster();
        self.app.scenario = Some(spec);
        self
    }
}

/// Everything submitters and workers share.
struct Shared {
    registry: Arc<PanelRegistry>,
    cfg: ServeConfig,
    state: Mutex<QueueState>,
    work: Condvar,
}

/// A coalesced batch popped from the queue.
struct Group {
    batch_id: u64,
    members: Vec<Pending>,
}

/// Bound on live engines per worker.  A prepared engine pins its panel via
/// `Arc`, so an unbounded cache would keep every panel a worker ever served
/// resident even after [`PanelRegistry`] evicts it — the cache must be
/// bounded for the registry bound to mean anything.
const ENGINE_CACHE_CAP: usize = 8;

/// One worker's engine cache: the live [`Engine`] per (panel, spec) pair it
/// has served, bounded by [`ENGINE_CACHE_CAP`] with least-recently-used
/// eviction.  Engines stay on their worker thread for their whole life, so
/// the trait needs no `Send` bound.
struct EngineCache {
    entries: HashMap<(String, EngineSpec), (Box<dyn Engine>, u64)>,
    tick: u64,
    /// Lookup counters since the last [`EngineCache::take_counters`] drain —
    /// workers fold them into the shared [`ServiceStats`] after each group,
    /// so `serve-stats/v1` shows live hit/miss/eviction rates per shard.
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl EngineCache {
    fn new() -> EngineCache {
        EngineCache {
            entries: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Fetch the cached engine for `key`, building and inserting it when
    /// absent (evicting the least-recently-used entry past the cap).
    fn get_or_build<F: FnOnce() -> Box<dyn Engine>>(
        &mut self,
        key: &(String, EngineSpec),
        build: F,
    ) -> &mut Box<dyn Engine> {
        self.tick += 1;
        let tick = self.tick;
        if self.entries.contains_key(key) {
            self.hits += 1;
        } else {
            self.misses += 1;
            while self.entries.len() >= ENGINE_CACHE_CAP {
                let victim = self
                    .entries
                    .iter()
                    .min_by_key(|(_, (_, last_used))| *last_used)
                    .map(|(k, _)| k.clone())
                    .expect("cache at capacity is nonempty");
                self.entries.remove(&victim);
                self.evictions += 1;
            }
            self.entries.insert(key.clone(), (build(), tick));
        }
        let slot = self.entries.get_mut(key).expect("just ensured present");
        slot.1 = tick;
        &mut slot.0
    }

    fn remove(&mut self, key: &(String, EngineSpec)) {
        self.entries.remove(key);
    }

    /// Drain the counters accumulated since the last call.
    fn take_counters(&mut self) -> (u64, u64, u64) {
        let drained = (self.hits, self.misses, self.evictions);
        self.hits = 0;
        self.misses = 0;
        self.evictions = 0;
        drained
    }
}

/// The multi-tenant imputation service: a panel registry, a bounded
/// coalescing queue and a worker pool.  See the [module docs](self) for the
/// execution model; construction is [`Service::start`], teardown is
/// [`Service::shutdown`] (or drop), both of which drain already-admitted
/// requests before the workers exit.
pub struct Service {
    shared: Arc<Shared>,
    next_id: AtomicU64,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Service {
    /// Spawn the worker pool and start serving.
    pub fn start(registry: Arc<PanelRegistry>, cfg: ServeConfig) -> Service {
        let n_workers = cfg.workers.max(1);
        let shared = Arc::new(Shared {
            registry,
            cfg,
            state: Mutex::new(QueueState::default()),
            work: Condvar::new(),
        });
        let workers = (0..n_workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("serve-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn serve worker")
            })
            .collect();
        Service {
            shared,
            next_id: AtomicU64::new(0),
            workers,
        }
    }

    /// Admit a request.  Sheds fast with a typed error — `admission:` when
    /// the request is empty, the queue is full or the service is shutting
    /// down; `deadline:` when the queue-age estimate already exceeds the
    /// request's `deadline_ms`; `quota:` when the tenant's token bucket is
    /// empty — all before any engine work is spent.
    pub fn submit(&self, req: ImputeRequest) -> Result<Ticket, String> {
        // Span origin AND the request's age origin: everything from here on
        // (admission checks included) counts against queue wait / deadlines.
        let accepted = Instant::now();
        let mut st = self.shared.state.lock().expect(POISONED);
        if req.targets.is_empty() {
            // Declared width: an empty explicit set and a zero-wide deferred
            // mint are both rejected up front.
            st.stats.rejected += 1;
            return Err("admission: request has no targets".into());
        }
        if st.shutdown {
            st.stats.rejected += 1;
            return Err("admission: service is shutting down".into());
        }
        if st.pending.len() >= self.shared.cfg.queue_capacity {
            st.stats.rejected += 1;
            return Err(format!(
                "admission: queue full ({} pending, capacity {})",
                st.pending.len(),
                self.shared.cfg.queue_capacity
            ));
        }
        // Deadline first (it spends nothing), then quota (it spends a
        // token): a doomed deadline never burns a tenant's budget.
        if let Some(dl) = req.deadline_ms {
            let est = st.estimated_wait_seconds(self.shared.cfg.workers);
            if est * 1e3 > dl as f64 {
                st.stats.rejected += 1;
                st.stats.shed_deadline += 1;
                return Err(format!(
                    "deadline: estimated queue wait {:.1} ms exceeds the {dl} ms budget \
                     ({} pending)",
                    est * 1e3,
                    st.pending.len()
                ));
            }
        }
        if let (Some(tenant), Some(quota)) =
            (req.tenant.as_deref(), self.shared.cfg.quota.as_ref())
        {
            if !st.take_token(tenant, quota, Instant::now()) {
                st.stats.rejected += 1;
                st.stats.shed_quota += 1;
                return Err(format!(
                    "quota: tenant {tenant:?} is out of tokens \
                     (rate {}/s, burst {})",
                    quota.rate_per_s, quota.burst
                ));
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        st.stats.accepted += 1;
        let (tx, rx) = mpsc::channel();
        let (parts_tx, parts_rx) = if req.stream.is_some() {
            let (ptx, prx) = mpsc::channel();
            (Some(ptx), Some(prx))
        } else {
            (None, None)
        };
        let span = req.spans.then(|| RequestSpan {
            admitted_us: accepted.elapsed().as_micros() as u64,
            ..RequestSpan::default()
        });
        st.pending.push_back(Pending {
            id,
            req,
            enqueued: accepted,
            reply: tx,
            parts: parts_tx,
            span,
        });
        drop(st);
        // Wake every worker: idle ones race for the head, lingering ones
        // re-scan for batch-mates.
        self.shared.work.notify_all();
        Ok(Ticket {
            id,
            rx,
            parts: parts_rx,
        })
    }

    /// Submit and block for the result (the one-shot convenience path).
    pub fn submit_wait(&self, req: ImputeRequest) -> Result<ServeReport, String> {
        self.submit(req)?.wait()
    }

    /// Snapshot of the aggregate counters.
    pub fn stats(&self) -> ServiceStats {
        self.shared.state.lock().expect(POISONED).stats
    }

    /// Requests currently waiting for a worker (excludes in-flight work).
    pub fn queue_depth(&self) -> usize {
        self.shared.state.lock().expect(POISONED).pending.len()
    }

    /// The configuration this service was started with.
    pub fn config(&self) -> &ServeConfig {
        &self.shared.cfg
    }

    /// The shared panel registry.
    pub fn registry(&self) -> &Arc<PanelRegistry> {
        &self.shared.registry
    }

    /// Stop admitting, drain every already-admitted request, join the
    /// workers, and return the final counters.
    pub fn shutdown(mut self) -> ServiceStats {
        self.finish();
        self.stats()
    }

    fn finish(&mut self) {
        if self.workers.is_empty() {
            return;
        }
        self.shared.state.lock().expect(POISONED).shutdown = true;
        self.shared.work.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.finish();
    }
}

/// One pool worker: pop coalesced groups until shutdown drains the queue,
/// folding the worker-local engine-cache counters into the shared stats
/// after each group so snapshots never lag by more than one batch.
fn worker_loop(shared: &Shared, worker: usize) {
    let mut engines = EngineCache::new();
    while let Some(group) = next_group(shared) {
        run_group(shared, &mut engines, group, worker);
        let (hits, misses, evictions) = engines.take_counters();
        if hits | misses | evictions != 0 {
            let mut st = shared.state.lock().expect(POISONED);
            st.stats.cache_hits += hits;
            st.stats.cache_misses += misses;
            st.stats.cache_evictions += evictions;
        }
    }
}

/// Pop the next coalesced group: the head request plus every same-key
/// pending request within the target budget, lingering up to the policy's
/// window for stragglers (never past shutdown).
fn next_group(shared: &Shared) -> Option<Group> {
    let policy = shared.cfg.coalesce;
    let mut st = shared.state.lock().expect(POISONED);
    let first = loop {
        if let Some(p) = st.pending.pop_front() {
            break p;
        }
        if st.shutdown {
            return None;
        }
        st = shared.work.wait(st).expect(POISONED);
    };
    let panel_key = first.req.panel.clone();
    let spec = first.req.engine;
    let mut total = first.req.targets.declared_len();
    let mut members = vec![first];
    if !policy.is_off() {
        let deadline = Instant::now() + policy.max_linger;
        loop {
            total = st.drain_matching(
                (panel_key.as_str(), spec),
                &mut members,
                total,
                policy.max_batch_targets,
            );
            if total >= policy.max_batch_targets || st.shutdown {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (relocked, _timeout) = shared
                .work
                .wait_timeout(st, deadline - now)
                .expect(POISONED);
            st = relocked;
        }
    }
    st.next_batch_id += 1;
    let batch_id = st.next_batch_id;
    st.stats.batches += 1;
    st.stats.coalesced_requests += members.len() as u64;
    Some(Group { batch_id, members })
}

/// Execute one coalesced group: resolve the panel, materialise every
/// member's targets (explicit sets are shape-checked; deferred mints run
/// HERE, in the pool, never on the stream-reader thread), bind the cached
/// engine (once per group when `prepare` is target-independent, once per
/// request when it validates targets), then execute.  Multi-request groups
/// on the event plane merge their targets into one wave sweep
/// ([`run_merged_wave`]); everything else serves each member as its own
/// [`TargetBatch`].  Every failure, panics included, degrades to
/// per-request errors.
fn run_group(shared: &Shared, engines: &mut EngineCache, group: Group, worker: usize) {
    let Group {
        batch_id,
        mut members,
    } = group;
    let panel_name = members[0].req.panel.clone();
    let spec = members[0].req.engine;

    // Queue wait ends here for every member: bucket it into the shared
    // histogram (one lock per group) and stamp opted-in spans.
    {
        let mut st = shared.state.lock().expect(POISONED);
        for p in &mut members {
            let us = p.age_us();
            st.stats.queue_wait_hist[crate::obs::latency_bucket(us)] += 1;
            if let Some(s) = p.span.as_mut() {
                s.mark_dequeued(us);
            }
        }
    }

    // Guarded like the engine calls: a panicking resolve (or any future
    // pre-engine step) must degrade to per-request errors, never kill the
    // worker and strand the queue.
    let panel = match guard("resolve", || shared.registry.resolve(&panel_name)) {
        Ok(p) => p,
        Err(e) => {
            for p in members {
                finish(shared, p, Err(e.clone()));
            }
            return;
        }
    };

    // Materialise targets per member: a malformed request (ragged targets,
    // over-cap mint) fails alone, never its batch-mates.
    let n_mark = panel.panel().n_mark();
    let mut good: Vec<(Pending, Vec<TargetHaplotype>)> = Vec::with_capacity(members.len());
    for mut p in members {
        let materialised = match std::mem::take(&mut p.req.targets) {
            RequestTargets::Explicit(ts) => {
                if ts.iter().all(|t| t.n_mark() == n_mark) {
                    Ok(ts)
                } else {
                    Err(format!(
                        "target/panel marker mismatch (panel {panel_name:?} has {n_mark} markers)"
                    ))
                }
            }
            RequestTargets::Mint { count, seed } => {
                guard("mint", || panel.minted_targets(count, seed))
            }
        };
        match materialised {
            Ok(ts) => {
                let us = p.age_us();
                if let Some(s) = p.span.as_mut() {
                    s.mark_minted(us);
                }
                good.push((p, ts));
            }
            Err(e) => finish(shared, p, Err(e)),
        }
    }

    // Execution starts NOW: everything since `enqueued` — queue wait AND the
    // resolve/mint/validation work just done on this worker — is the
    // request's true age.  That age is what `queue_wait_seconds` reports and
    // what deadlines are re-checked against (a deferred mint's cost must be
    // visible to both; admission could only estimate it).
    let exec_start = Instant::now();
    let mut runnable: Vec<(Pending, Vec<TargetHaplotype>)> = Vec::with_capacity(good.len());
    for (p, ts) in good {
        let age_ms = exec_start.duration_since(p.enqueued).as_secs_f64() * 1e3;
        match p.req.deadline_ms {
            Some(dl) if age_ms > dl as f64 => {
                let e = format!(
                    "deadline: request aged {age_ms:.1} ms (queue wait + mint) past its \
                     {dl} ms budget before execution"
                );
                finish(shared, p, Err(e));
            }
            _ => runnable.push((p, ts)),
        }
    }
    let mut good = runnable;
    if good.is_empty() {
        return;
    }

    // Streamed requests never coalesce (see `QueueState::drain_matching`),
    // so a stream spec on the head means a singleton group: run it window-
    // by-window, emitting parts as cores complete.
    if good.len() == 1 && good[0].0.req.stream.is_some() {
        let (mut p, targets) = good.into_iter().next().expect("len checked above");
        let ctx = RequestCtx {
            batch_id,
            width: 1,
            queue_wait_seconds: exec_start.duration_since(p.enqueued).as_secs_f64(),
            worker,
        };
        let result = run_streamed(shared, &panel, &p, targets, &ctx);
        let us = p.age_us();
        if let Some(s) = p.span.as_mut() {
            // Window sessions build their own engines, so there is no
            // distinct prepare stamp — it forward-fills at close-out.
            s.mark_run(us);
        }
        if let Ok(r) = &result {
            note_service_time(shared, r.report.host_seconds, 1);
        }
        finish(shared, p, result);
        return;
    }

    let key = (panel_name, spec);
    let mut had_error = false;
    {
        let engine =
            engines.get_or_build(&key, || build_engine(spec, &shared.cfg.app, shared.cfg.mapping));
        let width = good.len();
        // Target-independent prepares (panel binding, runtime opening) run
        // once per group against a target-less workload — zero copies of
        // observation data.  Engines whose prepare validates the request's
        // targets (the interp plane's annotation-grid check) are re-prepared
        // per request below, exactly like a solo session, so one bad
        // request's validation failure never poisons its batch-mates.
        let per_request_prepare = engine.prepare_inspects_targets();
        let group_bind = if per_request_prepare {
            Ok(())
        } else {
            Workload::from_shared(panel.panel_arc(), Vec::new())
                .and_then(|bind| guard("prepare", || engine.prepare(&bind)))
        };
        match group_bind {
            Err(e) => {
                had_error = true;
                for (p, _) in good {
                    finish(shared, p, Err(e.clone()));
                }
            }
            Ok(()) => {
                // The group-wide bind just completed (or was deferred to the
                // per-request path, which re-stamps with its own prepare).
                if !per_request_prepare {
                    for (p, _) in good.iter_mut() {
                        let us = p.age_us();
                        if let Some(s) = p.span.as_mut() {
                            s.mark_prepared(us);
                        }
                    }
                }
                // Event-plane groups merge every member's targets into ONE
                // wave sweep: batch-width-invariant numerics make the merged
                // run bit-identical per target to each member's solo run.
                if spec == EngineSpec::Event && width > 1 {
                    had_error |= run_merged_wave(
                        shared,
                        engine.as_mut(),
                        &panel,
                        good,
                        batch_id,
                        width,
                        exec_start,
                        worker,
                    );
                } else {
                    for (mut p, targets) in good {
                        let ctx = RequestCtx {
                            batch_id,
                            width,
                            queue_wait_seconds: exec_start
                                .duration_since(p.enqueued)
                                .as_secs_f64(),
                            worker,
                        };
                        let result = if per_request_prepare {
                            prepare_and_serve(
                                shared,
                                engine.as_mut(),
                                &panel,
                                &mut p,
                                &targets,
                                &ctx,
                                &mut had_error,
                            )
                        } else {
                            serve_one(
                                shared,
                                engine.as_mut(),
                                &panel,
                                &mut p,
                                &targets,
                                &ctx,
                                &mut had_error,
                            )
                        };
                        had_error |= result.is_err();
                        finish(shared, p, result);
                    }
                }
            }
        }
    }
    // Engines that errored (or panicked) are rebuilt from scratch next time
    // rather than trusted to have consistent internal state.
    if had_error {
        engines.remove(&key);
    }
}

/// Service-side labels for one request's execution.
struct RequestCtx {
    batch_id: u64,
    width: usize,
    queue_wait_seconds: f64,
    worker: usize,
}

/// Run a multi-request event-plane group as ONE wave: concatenate every
/// member's targets into a single [`TargetBatch`] (one lane-group sweep of
/// the panel), then scatter the dosage rows back per request.  Returns
/// whether the caller's cached engine must be evicted (it failed or was
/// retried on a fresh engine and can no longer be trusted).  The shared
/// sweep's timings/metrics are reported on every member (one sweep served
/// them all).
#[allow(clippy::too_many_arguments)]
fn run_merged_wave(
    shared: &Shared,
    engine: &mut dyn Engine,
    panel: &RegisteredPanel,
    good: Vec<(Pending, Vec<TargetHaplotype>)>,
    batch_id: u64,
    width: usize,
    exec_start: Instant,
    worker: usize,
) -> bool {
    // Drain the owned target vectors into one wave — no cloning; only the
    // per-member row counts are needed for the scatter.
    let mut all: Vec<TargetHaplotype> = Vec::with_capacity(
        good.iter().map(|(_, ts)| ts.len()).sum(),
    );
    let mut members: Vec<(Pending, usize)> = Vec::with_capacity(good.len());
    for (p, ts) in good {
        members.push((p, ts.len()));
        all.extend(ts);
    }
    let total = all.len();
    let t0 = Instant::now();
    let mut attempt = guard("run", || engine.run(&TargetBatch::new(&all)));
    let mut host_seconds = t0.elapsed().as_secs_f64();
    let mut retried = false;
    if let Err(first) = &attempt {
        // One retry on a freshly built engine (satellite of the fault plane):
        // the cached engine may have been left mid-sweep by the panic, so the
        // caller evicts it whether or not the retry lands.
        let first = first.clone();
        retried = true;
        shared.state.lock().expect(POISONED).stats.retried += 1;
        let spec = members[0].0.req.engine;
        let t1 = Instant::now();
        attempt = retry_on_fresh_engine(shared, panel, spec, &all)
            .map_err(|e| format!("{first}; retry on a fresh engine failed: {e}"));
        host_seconds = t1.elapsed().as_secs_f64();
    }
    let out = match attempt {
        Ok(o) if o.dosages.len() == total => o,
        Ok(o) => {
            let e = format!(
                "event engine returned {} dosage rows for a {total}-target merged wave",
                o.dosages.len()
            );
            for (p, _) in members {
                finish(shared, p, Err(e.clone()));
            }
            return true;
        }
        Err(e) => {
            for (p, _) in members {
                finish(shared, p, Err(e.clone()));
            }
            return true;
        }
    };
    {
        let mut st = shared.state.lock().expect(POISONED);
        st.stats.merged_waves += 1;
        st.note_service_time(host_seconds / width.max(1) as f64);
    }
    note_recovery(shared, out.metrics.as_ref());
    let mut rows = out.dosages.into_iter();
    for (mut p, n) in members {
        let us = p.age_us();
        if let Some(s) = p.span.as_mut() {
            s.mark_run(us);
            s.merged_wave = true;
        }
        let dosages: Vec<Vec<f32>> = rows.by_ref().take(n).collect();
        let ctx = RequestCtx {
            batch_id,
            width,
            queue_wait_seconds: exec_start.duration_since(p.enqueued).as_secs_f64(),
            worker,
        };
        let report = make_report(
            shared,
            panel,
            &p,
            &ctx,
            n,
            dosages,
            out.sim_seconds,
            out.metrics.clone(),
            host_seconds,
        );
        finish(shared, p, Ok(report));
    }
    retried
}

/// Prepare the engine on this request's own workload, then serve it — the
/// path for engines whose `prepare` validates targets; identical to what a
/// solo `ImputeSession` run does.
#[allow(clippy::too_many_arguments)]
fn prepare_and_serve(
    shared: &Shared,
    engine: &mut dyn Engine,
    panel: &RegisteredPanel,
    p: &mut Pending,
    targets: &[TargetHaplotype],
    ctx: &RequestCtx,
    evict: &mut bool,
) -> Result<ServeReport, String> {
    let wl = Workload::from_shared(panel.panel_arc(), targets.to_vec())?;
    guard("prepare", || engine.prepare(&wl))?;
    let us = p.age_us();
    if let Some(s) = p.span.as_mut() {
        s.mark_prepared(us);
    }
    serve_one(shared, engine, panel, p, targets, ctx, evict)
}

/// Run one member request as its own batch and assemble its report.  A run
/// that fails (panics included) is retried ONCE on a freshly built engine —
/// transient faults (a poisoned cached engine, a recoverable simulator
/// wobble) answer in-band instead of erroring; `evict` is raised either way
/// so the suspect cached engine is rebuilt before its next group.
#[allow(clippy::too_many_arguments)]
fn serve_one(
    shared: &Shared,
    engine: &mut dyn Engine,
    panel: &RegisteredPanel,
    p: &mut Pending,
    targets: &[TargetHaplotype],
    ctx: &RequestCtx,
    evict: &mut bool,
) -> Result<ServeReport, String> {
    let n_targets = targets.len();
    let t0 = Instant::now();
    let mut attempt = guard("run", || engine.run(&TargetBatch::new(targets)));
    let mut host_seconds = t0.elapsed().as_secs_f64();
    if let Err(first) = &attempt {
        let first = first.clone();
        *evict = true;
        shared.state.lock().expect(POISONED).stats.retried += 1;
        let t1 = Instant::now();
        attempt = retry_on_fresh_engine(shared, panel, p.req.engine, targets)
            .map_err(|e| format!("{first}; retry on a fresh engine failed: {e}"));
        host_seconds = t1.elapsed().as_secs_f64();
    }
    let out = attempt?;
    let us = p.age_us();
    if let Some(s) = p.span.as_mut() {
        s.mark_run(us);
    }
    note_service_time(shared, host_seconds, 1);
    note_recovery(shared, out.metrics.as_ref());
    if out.dosages.len() != n_targets {
        return Err(format!(
            "{} engine returned {} dosage rows for a {}-target request",
            p.req.engine.name(),
            out.dosages.len(),
            n_targets
        ));
    }
    Ok(make_report(
        shared,
        panel,
        p,
        ctx,
        n_targets,
        out.dosages,
        out.sim_seconds,
        out.metrics,
        host_seconds,
    ))
}

/// Assemble one request's `serve-report/v1` document.
#[allow(clippy::too_many_arguments)]
fn make_report(
    shared: &Shared,
    panel: &RegisteredPanel,
    p: &Pending,
    ctx: &RequestCtx,
    n_targets: usize,
    dosages: Vec<Vec<f32>>,
    sim_seconds: Option<f64>,
    metrics: Option<crate::poets::metrics::SimMetrics>,
    host_seconds: f64,
) -> ServeReport {
    ServeReport {
        request_id: p.id,
        panel: panel.name().to_string(),
        batch_id: ctx.batch_id,
        coalesce_width: ctx.width,
        queue_wait_seconds: ctx.queue_wait_seconds,
        worker: ctx.worker,
        report: ImputeReport {
            engine: p.req.engine,
            n_hap: panel.panel().n_hap(),
            n_mark: panel.panel().n_mark(),
            n_targets,
            panel: Some(panel.name().to_string()),
            provenance: panel.recipe().copied(),
            batch_size: n_targets,
            n_batches: 1,
            windows: None,
            boards: shared.cfg.app.cluster.n_boards,
            states_per_thread: shared.cfg.app.states_per_thread,
            threads: shared.cfg.app.sim.threads.unwrap_or(1),
            mapping: shared.cfg.mapping,
            dosages,
            accuracy: None,
            host_seconds,
            sim_seconds,
            metrics,
            stream: None,
            trace: None,
        },
        span: None,
    }
}

/// Run one streamed request window-by-window: validate the plan, run each
/// window as its own [`ImputeSession`](crate::session::ImputeSession)
/// (windowed workloads have differing marker spans, so the worker's
/// whole-panel engine cache does not apply), push each window's core-span
/// dosage rows through the request's [`ServePart`] channel as it completes,
/// then stitch the full report exactly like `genomics::window::run_windowed`
/// — the final report is bit-identical to the non-streamed run.
fn run_streamed(
    shared: &Shared,
    panel: &RegisteredPanel,
    p: &Pending,
    targets: Vec<TargetHaplotype>,
    ctx: &RequestCtx,
) -> Result<ServeReport, String> {
    let stream = p.req.stream.expect("caller checked stream.is_some()");
    let spec = p.req.engine;
    let full = Workload::from_shared(panel.panel_arc(), targets)?;
    let plan = crate::genomics::window::WindowPlan::new(
        panel.panel().n_mark(),
        stream.window,
        stream.overlap,
    )?;
    crate::genomics::window::validate_windowed(&full, &plan, spec)?;
    let n_windows = plan.len();
    let mut reports = Vec::with_capacity(n_windows);
    for (i, win) in plan.windows().iter().enumerate() {
        let wl = plan.slice_workload(&full, win);
        let report = guard("run", || {
            crate::session::ImputeSession::new(wl)
                .engine(spec)
                .app_config(shared.cfg.app.clone())
                .mapping(shared.cfg.mapping)
                .run()
        })?;
        if let Some(tx) = &p.parts {
            let rows: Vec<Vec<f32>> = report
                .dosages
                .iter()
                .map(|row| row[win.core_start - win.start..win.core_end - win.start].to_vec())
                .collect();
            // A client that stopped reading parts just misses them; the
            // stitched final report still answers the ticket.
            let _ = tx.send(ServePart {
                request_id: p.id,
                window_index: i,
                n_windows,
                core_start: win.core_start,
                core_end: win.core_end,
                rows,
            });
        }
        reports.push(report);
    }
    let mut merged = crate::genomics::window::stitch_reports(&full, &plan, reports)?;
    note_recovery(shared, merged.metrics.as_ref());
    merged.panel = Some(panel.name().to_string());
    merged.provenance = panel.recipe().copied();
    Ok(ServeReport {
        request_id: p.id,
        panel: panel.name().to_string(),
        batch_id: ctx.batch_id,
        coalesce_width: ctx.width,
        queue_wait_seconds: ctx.queue_wait_seconds,
        worker: ctx.worker,
        report: merged,
        span: None,
    })
}

/// Rebuild the engine from scratch and rerun the request — the single
/// retry behind [`serve_one`]/[`run_merged_wave`].  The fresh engine is
/// prepared on the request's own workload (correct for both target-
/// independent and target-inspecting prepares) and dropped afterwards; the
/// caller evicts the suspect cached engine separately.
fn retry_on_fresh_engine(
    shared: &Shared,
    panel: &RegisteredPanel,
    spec: EngineSpec,
    targets: &[TargetHaplotype],
) -> Result<EngineOutput, String> {
    let mut fresh = build_engine(spec, &shared.cfg.app, shared.cfg.mapping);
    let wl = Workload::from_shared(panel.panel_arc(), targets.to_vec())?;
    guard("prepare", || fresh.prepare(&wl))?;
    guard("run", || fresh.run(&TargetBatch::new(targets)))
}

/// Fold one successful run's recovery telemetry into the admission state:
/// an event-plane run that failed tiles (or replayed supersteps) marks the
/// service **degraded** — `estimated_wait_seconds` stretches by
/// [`queue::DEGRADED_WAIT_FACTOR`] until a clean event run clears the flag.
/// Engines without simulator metrics never touch the flag.
fn note_recovery(shared: &Shared, metrics: Option<&crate::poets::metrics::SimMetrics>) {
    if let Some(m) = metrics {
        shared
            .state
            .lock()
            .expect(POISONED)
            .note_recovery(m.recovery_cycles, m.failed_tiles);
    }
}

/// Feed one engine run's wall time back into the admission-side service-time
/// EWMA (per request: the batch's host seconds split over its width).
fn note_service_time(shared: &Shared, host_seconds: f64, width: usize) {
    shared
        .state
        .lock()
        .expect(POISONED)
        .note_service_time(host_seconds / width.max(1) as f64);
}

/// Answer a request and bump the counters.  For span-opted requests the
/// timeline is closed out here (the `responded` stamp is the instant the
/// reply leaves for the ticket channel) and attached to successful replies.
fn finish(shared: &Shared, mut p: Pending, mut result: Result<ServeReport, String>) {
    if let (Some(span), Ok(r)) = (p.span.as_mut(), result.as_mut()) {
        span.coalesced_with = r.coalesce_width as u32;
        span.mark_responded(p.enqueued.elapsed().as_micros() as u64);
        r.span = Some(*span);
    }
    {
        let mut st = shared.state.lock().expect(POISONED);
        match &result {
            Ok(_) => st.stats.completed += 1,
            Err(e) => {
                st.stats.failed += 1;
                // Worker-side deadline expiry (queue + mint overran the
                // budget) is a shed, not an engine failure.
                if e.starts_with("deadline:") {
                    st.stats.shed_deadline += 1;
                }
            }
        }
    }
    // A client that dropped its ticket just doesn't read the answer.
    let _ = p.reply.send(result);
}

/// Convert engine panics (e.g. a mapping capacity assert on an oversized
/// request) into per-request errors so one bad request cannot kill a pool
/// worker and starve the queue.
fn guard<T>(phase: &str, f: impl FnOnce() -> Result<T, String>) -> Result<T, String> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".into());
            Err(format!("{phase} panicked: {msg}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    const PANEL: &str = "synth:hap=8,mark=21,annot=0.2,seed=11";

    fn service(cfg: ServeConfig) -> Service {
        Service::start(Arc::new(PanelRegistry::new()), cfg)
    }

    fn request(service: &Service, engine: EngineSpec, n: usize, seed: u64) -> ImputeRequest {
        let panel = service.registry().resolve(PANEL).unwrap();
        ImputeRequest::new(PANEL, engine, panel.synthetic_targets(n, seed).unwrap())
    }

    #[test]
    fn submit_wait_roundtrip() {
        let svc = service(ServeConfig::default());
        let report = svc
            .submit_wait(request(&svc, EngineSpec::Baseline, 2, 1))
            .unwrap();
        assert_eq!(report.dosages().len(), 2);
        assert_eq!(report.report.n_mark, 21);
        assert_eq!(report.report.engine, EngineSpec::Baseline);
        assert!(report.coalesce_width >= 1);
        assert!(report.queue_wait_seconds >= 0.0);
        let stats = svc.shutdown();
        assert_eq!(stats.accepted, 1);
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.failed, 0);
        assert!(stats.batches >= 1);
    }

    #[test]
    fn empty_requests_are_rejected_at_admission() {
        let svc = service(ServeConfig::default());
        let err = svc
            .submit(ImputeRequest::new(
                PANEL,
                EngineSpec::Baseline,
                RequestTargets::Explicit(Vec::new()),
            ))
            .unwrap_err();
        assert!(err.starts_with("admission:"), "{err}");
        // A zero-wide deferred mint is equally empty at admission time.
        let err = svc
            .submit(ImputeRequest::new(
                PANEL,
                EngineSpec::Baseline,
                RequestTargets::Mint { count: 0, seed: 1 },
            ))
            .unwrap_err();
        assert!(err.starts_with("admission:"), "{err}");
        assert_eq!(svc.shutdown().rejected, 2);
    }

    #[test]
    fn unknown_panel_fails_the_request_not_the_worker() {
        let svc = service(ServeConfig::default().workers(1));
        let err = svc
            .submit_wait(ImputeRequest::new(
                "nonexistent",
                EngineSpec::Baseline,
                vec![crate::model::panel::TargetHaplotype::new(vec![-1, 0, 1])],
            ))
            .unwrap_err();
        assert!(err.contains("unknown panel"), "{err}");
        // The worker survived: a valid follow-up request still works.
        let ok = svc.submit_wait(request(&svc, EngineSpec::Rank1, 1, 2));
        assert!(ok.is_ok(), "{ok:?}");
        let stats = svc.shutdown();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn marker_mismatch_fails_individually() {
        let svc = service(ServeConfig::default().workers(1));
        let err = svc
            .submit_wait(ImputeRequest::new(
                PANEL,
                EngineSpec::Baseline,
                vec![crate::model::panel::TargetHaplotype::new(vec![-1; 7])],
            ))
            .unwrap_err();
        assert!(err.contains("marker mismatch"), "{err}");
        let stats = svc.shutdown();
        assert_eq!(stats.failed, 1);
    }

    #[test]
    fn queue_capacity_sheds_load() {
        // One worker, capacity 1: stuff the queue faster than it drains and
        // at least the capacity bound must hold (no unbounded growth).
        let svc = service(
            ServeConfig::default()
                .workers(1)
                .queue_capacity(1)
                .coalesce(CoalescePolicy {
                    max_batch_targets: 1,
                    max_linger: Duration::ZERO,
                }),
        );
        let mut tickets = Vec::new();
        let mut rejected = 0u64;
        for i in 0..32 {
            match svc.submit(request(&svc, EngineSpec::Baseline, 1, i)) {
                Ok(t) => tickets.push(t),
                Err(e) => {
                    assert!(e.starts_with("admission: queue full"), "{e}");
                    rejected += 1;
                }
            }
        }
        for t in tickets {
            t.wait().unwrap();
        }
        let stats = svc.shutdown();
        assert_eq!(stats.rejected, rejected);
        assert_eq!(stats.accepted + stats.rejected, 32);
        assert_eq!(stats.completed, stats.accepted);
    }

    #[test]
    fn coalescing_merges_same_key_requests() {
        // Single worker + generous linger: submit a burst, then check at
        // least one batch served more than one request.  (The window is
        // deliberately much larger than the submit loop so slow CI schedulers
        // can't starve the coalescer.)
        let svc = service(ServeConfig::default().workers(1).coalesce(CoalescePolicy {
            max_batch_targets: 16,
            max_linger: Duration::from_millis(200),
        }));
        let tickets: Vec<Ticket> = (0..4)
            .map(|i| svc.submit(request(&svc, EngineSpec::Rank1, 1, i)).unwrap())
            .collect();
        let reports: Vec<ServeReport> =
            tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        let max_width = reports.iter().map(|r| r.coalesce_width).max().unwrap();
        assert!(
            max_width >= 2,
            "expected some coalescing under a 200ms linger; widths: {:?}",
            reports.iter().map(|r| r.coalesce_width).collect::<Vec<_>>()
        );
        let stats = svc.shutdown();
        assert_eq!(stats.completed, 4);
        assert!(stats.batches < 4, "linger should have merged batches");
        assert!(stats.mean_batch_width() > 1.0);
    }

    #[test]
    fn shutdown_drains_admitted_requests() {
        let svc = service(ServeConfig::default().workers(2));
        let tickets: Vec<Ticket> = (0..6)
            .map(|i| svc.submit(request(&svc, EngineSpec::Baseline, 1, i)).unwrap())
            .collect();
        let stats = svc.shutdown(); // joins workers; queue must be drained
        assert_eq!(stats.completed + stats.failed, 6);
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn engine_cache_is_bounded_with_lru_eviction() {
        let mut cache = EngineCache::new();
        let app = RawAppConfig::default();
        let key = |i: usize| (format!("panel-{i}"), EngineSpec::Baseline);
        for i in 0..ENGINE_CACHE_CAP + 4 {
            cache.get_or_build(&key(i), || {
                build_engine(EngineSpec::Baseline, &app, MappingStrategy::Manual2d)
            });
        }
        assert_eq!(cache.entries.len(), ENGINE_CACHE_CAP, "cache must stay bounded");
        // The most recent key survives; the oldest was evicted.
        assert!(cache.entries.contains_key(&key(ENGINE_CACHE_CAP + 3)));
        assert!(!cache.entries.contains_key(&key(0)));
        // Every insert missed; each past-capacity insert evicted one victim.
        assert_eq!(
            cache.take_counters(),
            (0, ENGINE_CACHE_CAP as u64 + 4, 4),
            "expected all-miss fills with 4 evictions"
        );
        // Touching an entry refreshes it past newer insertions.
        cache.get_or_build(&key(5), || unreachable!("cached"));
        cache.get_or_build(&key(100), || {
            build_engine(EngineSpec::Baseline, &app, MappingStrategy::Manual2d)
        });
        assert!(cache.entries.contains_key(&key(5)), "freshly-used entry evicted");
        assert_eq!(cache.take_counters(), (1, 1, 1), "hit + evicting miss");
        assert_eq!(cache.take_counters(), (0, 0, 0), "drain resets");
        cache.remove(&key(5));
        assert!(!cache.entries.contains_key(&key(5)));
    }

    #[test]
    fn cache_counters_reach_service_stats() {
        // Two requests against the same (panel, engine) on one worker: the
        // first misses (engine built), the second hits the worker cache.
        let svc = service(ServeConfig::default().workers(1).no_coalesce());
        svc.submit_wait(request(&svc, EngineSpec::Rank1, 1, 0)).unwrap();
        svc.submit_wait(request(&svc, EngineSpec::Rank1, 1, 1)).unwrap();
        let stats = svc.shutdown();
        assert_eq!(stats.cache_misses, 1, "one engine build");
        assert_eq!(stats.cache_hits, 1, "second request reuses it");
        assert_eq!(stats.cache_evictions, 0);
        // Both requests waited and ran, so both histograms saw them.
        assert_eq!(stats.queue_wait_hist.iter().sum::<u64>(), 2);
        assert_eq!(stats.service_hist.iter().sum::<u64>(), 2);
    }

    #[test]
    fn spans_are_opt_in_and_monotone() {
        let svc = service(ServeConfig::default().workers(1));
        let plain = svc
            .submit_wait(request(&svc, EngineSpec::Rank1, 1, 0))
            .unwrap();
        assert!(plain.span.is_none(), "spans are opt-in");
        let spanned = svc
            .submit_wait(request(&svc, EngineSpec::Rank1, 1, 1).with_spans())
            .unwrap();
        let span = spanned.span.expect("requested span");
        let stamps = [
            span.admitted_us,
            span.dequeued_us,
            span.minted_us,
            span.prepared_us,
            span.run_us,
            span.responded_us,
        ];
        assert!(
            stamps.windows(2).all(|w| w[0] <= w[1]),
            "span stamps must be monotone: {stamps:?}"
        );
        assert_eq!(span.coalesced_with as usize, spanned.coalesce_width);
        svc.shutdown();
    }

    #[test]
    fn oversized_event_request_errors_instead_of_killing_the_worker() {
        // A panel too big for the simulated cluster at the configured
        // soft-scheduling makes the mapping assert; the guard must convert
        // that into a per-request error and the worker must keep serving.
        let svc = service(ServeConfig::default().workers(1).states_per_thread(1));
        let big = "synth:hap=64,mark=512,seed=3";
        let panel = svc.registry().resolve(big).unwrap();
        let err = svc
            .submit_wait(ImputeRequest::new(
                big,
                EngineSpec::Event,
                panel.synthetic_targets(1, 0).unwrap(),
            ))
            .unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        let ok = svc.submit_wait(request(&svc, EngineSpec::Baseline, 1, 4));
        assert!(ok.is_ok(), "{ok:?}");
        svc.shutdown();
    }

    #[test]
    fn failed_run_is_retried_once_before_failing_in_band() {
        // A deterministically panicking request (mapping capacity assert)
        // fails its first run AND its fresh-engine retry: the error must
        // report both attempts, `retried` must count exactly one retry, and
        // the worker must keep serving afterwards.
        let svc = service(ServeConfig::default().workers(1).states_per_thread(1));
        let big = "synth:hap=64,mark=512,seed=3";
        let panel = svc.registry().resolve(big).unwrap();
        let err = svc
            .submit_wait(ImputeRequest::new(
                big,
                EngineSpec::Event,
                panel.synthetic_targets(1, 0).unwrap(),
            ))
            .unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("retry on a fresh engine failed"), "{err}");
        let ok = svc.submit_wait(request(&svc, EngineSpec::Baseline, 1, 4));
        assert!(ok.is_ok(), "{ok:?}");
        let stats = svc.shutdown();
        assert_eq!(stats.retried, 1, "exactly one fresh-engine retry");
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn recovered_runs_mark_the_service_degraded() {
        // Serve under a fault scenario that kills one tile mid-run: the
        // request still answers (remap-and-replay inside the engine), its
        // report carries the recovery telemetry, and the service marks
        // itself degraded so admission stretches wait estimates.
        let spec = crate::poets::scenario::ScenarioSpec::parse(
            "name=faulty,boards=2,tiles=2,cores=1,threads=2,failtile=0.1@5,ckpt=2",
        )
        .unwrap();
        let svc = service(
            ServeConfig::default()
                .workers(1)
                .states_per_thread(32)
                .scenario(spec),
        );
        let report = svc
            .submit_wait(request(&svc, EngineSpec::Event, 2, 9))
            .unwrap();
        let m = report.report.metrics.as_ref().expect("event runs report metrics");
        assert_eq!(m.failed_tiles, 1, "the scheduled tile death happened");
        assert!(m.recovery_cycles > 0, "recovery was charged");
        let stats = svc.stats();
        assert!(stats.degraded, "recovering service must report degraded");
        assert_eq!(stats.recovered_runs, 1);
        assert!(stats.recovery_cycles > 0);
        assert_eq!(stats.retried, 0, "in-engine recovery is not a serve retry");
        let final_stats = svc.shutdown();
        assert_eq!(final_stats.failed, 0, "faulted run still answered in-band");
        assert_eq!(final_stats.completed, 1);
    }

    #[test]
    fn tenant_quota_sheds_after_burst() {
        // rate 0 / burst 1: exactly one admitted request per tenant, ever.
        let svc = service(ServeConfig::default().workers(1).tenant_quota(0.0, 1.0));
        svc.submit_wait(request(&svc, EngineSpec::Baseline, 1, 0).tenant("acme"))
            .unwrap();
        let err = svc
            .submit(request(&svc, EngineSpec::Baseline, 1, 1).tenant("acme"))
            .unwrap_err();
        assert!(err.starts_with("quota:"), "{err}");
        // A different tenant, and tenant-less requests, are unaffected.
        svc.submit_wait(request(&svc, EngineSpec::Baseline, 1, 2).tenant("other"))
            .unwrap();
        svc.submit_wait(request(&svc, EngineSpec::Baseline, 1, 3))
            .unwrap();
        let stats = svc.shutdown();
        assert_eq!(stats.shed_quota, 1);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.completed, 3);
    }

    #[test]
    fn zero_deadline_expires_worker_side_and_counts_as_shed() {
        // An idle queue gives a zero wait estimate, so admission lets a
        // 0 ms deadline through — the worker's age re-check (which sees the
        // real queue + mint time) must then expire it in-band.
        let svc = service(ServeConfig::default().workers(1).no_coalesce());
        let err = svc
            .submit_wait(request(&svc, EngineSpec::Baseline, 1, 0).deadline_ms(0))
            .unwrap_err();
        assert!(err.starts_with("deadline:"), "{err}");
        // The worker survived and serves the next request.
        svc.submit_wait(request(&svc, EngineSpec::Baseline, 1, 1))
            .unwrap();
        let stats = svc.shutdown();
        assert_eq!(stats.shed_deadline, 1);
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn deadline_admission_sheds_on_backlog_estimate() {
        // Prime the service-time EWMA with one heavy completed request,
        // then stack a backlog behind a single worker: a 1 ms deadline on a
        // deep queue must shed AT ADMISSION (rejected, not failed).
        let heavy = "synth:hap=8,mark=20001,annot=0.1,seed=13";
        let svc = service(ServeConfig::default().workers(1).no_coalesce());
        let panel = svc.registry().resolve(heavy).unwrap();
        let targets = panel.synthetic_targets(8, 1).unwrap();
        svc.submit_wait(ImputeRequest::new(heavy, EngineSpec::Baseline, targets.clone()))
            .unwrap();

        let tickets: Vec<Ticket> = (0..4)
            .map(|_| {
                svc.submit(ImputeRequest::new(
                    heavy,
                    EngineSpec::Baseline,
                    targets.clone(),
                ))
                .unwrap()
            })
            .collect();
        // With >= 3 pending and a multi-ms EWMA, the estimate dwarfs 1 ms.
        let err = svc
            .submit(
                ImputeRequest::new(heavy, EngineSpec::Baseline, targets.clone()).deadline_ms(1),
            )
            .unwrap_err();
        assert!(err.starts_with("deadline:"), "{err}");
        for t in tickets {
            t.wait().unwrap();
        }
        let stats = svc.shutdown();
        assert_eq!(stats.shed_deadline, 1);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.failed, 0, "admission sheds never reach a worker");
    }

    #[test]
    fn minted_request_wait_charges_mint_time() {
        // Satellite: worker-side mint time must be visible in
        // `queue_wait_seconds`.  Same idle single-worker service, same
        // panel; the minted twin's wait includes drawing 64×20001
        // observations, the explicit twin's does not.  Min-of-3 filters
        // scheduler noise.
        let heavy = "synth:hap=8,mark=20001,annot=0.1,seed=17";
        let svc = service(ServeConfig::default().workers(1).no_coalesce());
        let panel = svc.registry().resolve(heavy).unwrap();
        let explicit = panel.minted_targets(64, 5).unwrap();

        let mut explicit_waits = Vec::new();
        let mut minted_waits = Vec::new();
        for trial in 0..3 {
            let r = svc
                .submit_wait(ImputeRequest::new(
                    heavy,
                    EngineSpec::Baseline,
                    explicit.clone(),
                ))
                .unwrap();
            explicit_waits.push(r.queue_wait_seconds);
            let r = svc
                .submit_wait(ImputeRequest::new(
                    heavy,
                    EngineSpec::Baseline,
                    RequestTargets::Mint {
                        count: 64,
                        seed: trial,
                    },
                ))
                .unwrap();
            minted_waits.push(r.queue_wait_seconds);
        }
        let explicit_min = explicit_waits.iter().cloned().fold(f64::MAX, f64::min);
        let minted_min = minted_waits.iter().cloned().fold(f64::MAX, f64::min);
        assert!(
            minted_min >= explicit_min,
            "mint time must be charged to the request's wait \
             (minted {minted_waits:?} vs explicit {explicit_waits:?})"
        );
        svc.shutdown();
    }

    #[test]
    fn streamed_request_emits_parts_and_matches_unstreamed() {
        let panel_spec = "synth:hap=8,mark=41,annot=0.2,seed=19";
        let svc = service(ServeConfig::default().workers(1));
        let panel = svc.registry().resolve(panel_spec).unwrap();
        let targets = panel.synthetic_targets(2, 3).unwrap();

        let plain = svc
            .submit_wait(ImputeRequest::new(
                panel_spec,
                EngineSpec::Rank1,
                targets.clone(),
            ))
            .unwrap();

        let ticket = svc
            .submit(
                ImputeRequest::new(panel_spec, EngineSpec::Rank1, targets)
                    .stream_windows(16, 4),
            )
            .unwrap();
        assert!(ticket.is_streaming());
        let mut parts = Vec::new();
        while let Some(part) = ticket.recv_part() {
            parts.push(part);
        }
        let streamed = ticket.wait().unwrap();

        // Parts partition the marker axis in order and match the final
        // stitched dosage matrix slice-for-slice.
        assert!(!parts.is_empty());
        assert_eq!(parts[0].core_start, 0);
        assert_eq!(parts.last().unwrap().core_end, 41);
        let n_windows = parts[0].n_windows;
        assert_eq!(parts.len(), n_windows);
        for (i, part) in parts.iter().enumerate() {
            assert_eq!(part.window_index, i);
            assert_eq!(part.request_id, streamed.request_id);
            if i > 0 {
                assert_eq!(part.core_start, parts[i - 1].core_end);
            }
            assert_eq!(part.rows.len(), 2);
            for (t, row) in part.rows.iter().enumerate() {
                assert_eq!(
                    row.as_slice(),
                    &streamed.dosages()[t][part.core_start..part.core_end],
                    "part {i} target {t} must match the stitched report"
                );
            }
        }
        assert_eq!(streamed.report.windows, Some(n_windows));
        // Windowed-vs-whole numerics differ only by windowing, which the
        // engine-equivalence suite bounds; here the shapes must agree.
        assert_eq!(streamed.dosages().len(), plain.dosages().len());
        assert_eq!(streamed.dosages()[0].len(), plain.dosages()[0].len());
        let stats = svc.shutdown();
        assert_eq!(stats.completed, 2);
    }
}
