//! `bench-serve` — load generators for the service.
//!
//! Two modes:
//!
//! * **Closed-loop** ([`run`]): sweeps worker counts × client counts ×
//!   coalescing on/off against one panel and engine.  Each simulated
//!   client is closed-loop (submit, block for the answer, repeat), the
//!   classic service-benchmark shape: offered load scales with client
//!   count and queueing shows up as latency rather than unbounded backlog.
//!   Per config the sweep reports throughput (requests/s), latency
//!   percentiles (p50/p99) and the achieved mean coalesce width — the
//!   numbers archived in `BENCH_serve.json` that the panel-level
//!   wave-batching perf work must beat (see `ROADMAP.md`).
//!
//! * **Open-loop** ([`run_open_loop`], `bench-serve --open-loop`): a
//!   Poisson arrival process at a fixed *offered* rate, swept over offered
//!   load × shard count × coalescing — the shape that exposes shedding and
//!   queueing growth, because arrivals do not slow down when the service
//!   does.  Per point it reports achieved throughput, sojourn percentiles
//!   (p50/p99/p999), shed/error counts (sheds never reached a worker;
//!   errors failed in one — both are **excluded from the latency
//!   percentiles**, which cover only the `latency_samples` successful
//!   responses, and a per-request error never aborts the sweep), and — in
//!   the uncongested single-shard
//!   regime — cross-checks the measured mean queue wait against the
//!   [`super::mmc`] M/M/c prediction built from the measured service-time
//!   mean.  Disagreement beyond the documented tolerance fails the run
//!   (the bench is a gate, not just a report).  Archived as
//!   `BENCH_serve_load.json`.

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::session::EngineSpec;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::percentile;
use crate::util::table::{Table, fmt_secs};
use crate::workload::panelgen::PanelConfig;

use super::queue::CoalescePolicy;
use super::{ImputeRequest, PanelRegistry, ServeConfig, Service, ShardedService, mmc};

/// Sweep shape.  Defaults are sized to finish in seconds on a laptop while
/// still showing the coalescing and pool-scaling effects.
#[derive(Clone, Debug)]
pub struct BenchServeOpts {
    /// Concurrent closed-loop clients (one sweep point per entry).
    pub clients: Vec<usize>,
    /// Service worker-pool sizes (one sweep point per entry; keep >= 2
    /// entries so the baseline records pool scaling).
    pub workers: Vec<usize>,
    /// Requests each client submits per sweep point.
    pub requests_per_client: usize,
    /// Targets per request.
    pub targets_per_request: usize,
    /// Compute plane under load.
    pub engine: EngineSpec,
    /// Panel spec every request hits (the multi-tenant hot-panel case).
    pub panel: String,
    /// Coalescing policy for the "on" half of the sweep.
    pub coalesce: CoalescePolicy,
}

impl Default for BenchServeOpts {
    fn default() -> Self {
        BenchServeOpts {
            clients: vec![1, 4, 8],
            workers: vec![1, 4],
            requests_per_client: 16,
            targets_per_request: 2,
            engine: EngineSpec::Rank1,
            panel: "synth:hap=16,mark=101,annot=0.1,seed=2023".into(),
            coalesce: CoalescePolicy {
                max_batch_targets: 16,
                max_linger: Duration::from_millis(1),
            },
        }
    }
}

/// One sweep point's measurements.
#[derive(Clone, Debug)]
pub struct BenchServeRow {
    pub workers: usize,
    pub clients: usize,
    pub coalesce: bool,
    pub requests: usize,
    pub wall_seconds: f64,
    pub requests_per_s: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_batch_width: f64,
    pub batches: u64,
}

/// Run the sweep.  Returns the rendered table and the
/// `poets-impute/bench-serve/v1` JSON document (the caller archives it as
/// `BENCH_serve.json`).
pub fn run(opts: &BenchServeOpts) -> Result<(String, Json), String> {
    if opts.clients.is_empty() || opts.workers.is_empty() {
        return Err("bench-serve: need at least one client and worker count".into());
    }
    if opts.requests_per_client == 0 || opts.targets_per_request == 0 {
        return Err("bench-serve: requests and targets per request must be >= 1".into());
    }
    let registry = Arc::new(PanelRegistry::new());
    // Resolve once up front: panel generation must not pollute the first
    // sweep point's latencies.
    registry.resolve(&opts.panel)?;

    let mut table = Table::new(&[
        "workers", "clients", "coalesce", "requests", "wall", "req/s", "p50", "p99",
        "mean width",
    ]);
    let mut rows = Vec::new();
    for &workers in &opts.workers {
        for &clients in &opts.clients {
            for coalesce in [false, true] {
                let row = sweep_point(&registry, opts, workers, clients, coalesce)?;
                table.row(vec![
                    row.workers.to_string(),
                    row.clients.to_string(),
                    if row.coalesce { "on" } else { "off" }.into(),
                    row.requests.to_string(),
                    fmt_secs(row.wall_seconds),
                    format!("{:.1}", row.requests_per_s),
                    format!("{:.2}ms", row.p50_ms),
                    format!("{:.2}ms", row.p99_ms),
                    format!("{:.2}", row.mean_batch_width),
                ]);
                rows.push(row);
            }
        }
    }
    Ok((table.render(), to_json(opts, &rows)))
}

/// One (workers, clients, coalesce) config: fresh service, closed-loop
/// clients with disjoint per-client target sets, merged latency stats.
fn sweep_point(
    registry: &Arc<PanelRegistry>,
    opts: &BenchServeOpts,
    workers: usize,
    clients: usize,
    coalesce: bool,
) -> Result<BenchServeRow, String> {
    let policy = if coalesce {
        opts.coalesce
    } else {
        CoalescePolicy::off()
    };
    let cfg = ServeConfig::default()
        .workers(workers)
        .coalesce(policy)
        .queue_capacity((clients * opts.requests_per_client).max(16));
    let service = Service::start(Arc::clone(registry), cfg);

    // Disjoint per-client targets, minted outside the timed section.
    let panel = registry.resolve(&opts.panel)?;
    let per_client: Vec<_> = (0..clients)
        .map(|c| panel.synthetic_targets(opts.targets_per_request, 0x10AD + c as u64))
        .collect::<Result<_, _>>()?;

    let start = Instant::now();
    let latencies: Vec<Vec<f64>> = thread::scope(|s| {
        let handles: Vec<_> = per_client
            .into_iter()
            .map(|targets| {
                let service = &service;
                let panel_name = opts.panel.clone();
                let engine = opts.engine;
                let n = opts.requests_per_client;
                s.spawn(move || -> Result<Vec<f64>, String> {
                    let mut lats = Vec::with_capacity(n);
                    for _ in 0..n {
                        let t0 = Instant::now();
                        service.submit_wait(ImputeRequest::new(
                            panel_name.clone(),
                            engine,
                            targets.clone(),
                        ))?;
                        lats.push(t0.elapsed().as_secs_f64());
                    }
                    Ok(lats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("bench client panicked"))
            .collect::<Result<Vec<Vec<f64>>, String>>()
    })?;
    let wall_seconds = start.elapsed().as_secs_f64();
    let stats = service.shutdown();

    let all: Vec<f64> = latencies.into_iter().flatten().collect();
    let requests = all.len();
    Ok(BenchServeRow {
        workers,
        clients,
        coalesce,
        requests,
        wall_seconds,
        requests_per_s: requests as f64 / wall_seconds.max(1e-12),
        p50_ms: percentile(&all, 50.0) * 1e3,
        p99_ms: percentile(&all, 99.0) * 1e3,
        mean_batch_width: stats.mean_batch_width(),
        batches: stats.batches,
    })
}

fn to_json(opts: &BenchServeOpts, rows: &[BenchServeRow]) -> Json {
    let mut json_rows = Json::Arr(Vec::new());
    for r in rows {
        let mut j = Json::obj();
        j.set("workers", r.workers)
            .set("clients", r.clients)
            .set("coalesce", r.coalesce)
            .set("requests", r.requests)
            .set("wall_seconds", r.wall_seconds)
            .set("requests_per_s", r.requests_per_s)
            .set("p50_ms", r.p50_ms)
            .set("p99_ms", r.p99_ms)
            .set("mean_batch_width", r.mean_batch_width)
            .set("batches", r.batches);
        json_rows.push(j);
    }
    let mut run_config = Json::obj();
    run_config
        .set("engine", opts.engine.name())
        .set("panel", opts.panel.as_str())
        .set(
            "workers",
            Json::Arr(opts.workers.iter().map(|&n| Json::Int(n as i64)).collect()),
        )
        .set(
            "clients",
            Json::Arr(opts.clients.iter().map(|&n| Json::Int(n as i64)).collect()),
        )
        .set("requests_per_client", opts.requests_per_client)
        .set("targets_per_request", opts.targets_per_request)
        .set("max_batch_targets", opts.coalesce.max_batch_targets)
        .set("linger_ms", opts.coalesce.max_linger.as_millis() as u64);

    let mut j = Json::obj();
    // Provenance (schema / git_commit / run_config): a tracked artifact
    // must name the commit and sweep shape that produced its numbers.
    crate::util::provenance::stamp(&mut j, "poets-impute/bench-serve/v1", run_config);
    j.set("bench", "serve")
        .set("engine", opts.engine.name())
        .set("panel", opts.panel.as_str())
        .set("requests_per_client", opts.requests_per_client)
        .set("targets_per_request", opts.targets_per_request)
        .set("rows", json_rows);
    j
}

/// Open-loop sweep shape.  Panels are registered per shard-slot
/// (`open-loop-<i>`) so multi-shard points actually spread traffic.
#[derive(Clone, Debug)]
pub struct OpenLoopOpts {
    /// Offered arrival rates (requests/s), one sweep point per entry.
    pub offered_rps: Vec<f64>,
    /// Shard counts to sweep.
    pub shards: Vec<usize>,
    /// Workers per shard.
    pub workers: usize,
    /// Arrivals generated per sweep point.
    pub requests: usize,
    /// Targets per request.
    pub targets_per_request: usize,
    /// Compute plane under load.
    pub engine: EngineSpec,
    /// Synthetic panel shape (one panel per shard slot, seeds differ).
    pub panel_hap: usize,
    pub panel_mark: usize,
    pub panel_annot: f64,
    /// Coalescing policy for the "on" half of the sweep.
    pub coalesce: CoalescePolicy,
    /// Admission queue capacity per shard (the shed threshold).
    pub queue_capacity: usize,
    /// Poisson-schedule seed (deterministic arrival times per point).
    pub seed: u64,
}

impl Default for OpenLoopOpts {
    fn default() -> Self {
        OpenLoopOpts {
            offered_rps: vec![25.0, 100.0, 400.0],
            shards: vec![1, 2],
            workers: 2,
            requests: 48,
            targets_per_request: 1,
            engine: EngineSpec::Rank1,
            panel_hap: 16,
            panel_mark: 101,
            panel_annot: 0.1,
            coalesce: CoalescePolicy {
                max_batch_targets: 16,
                max_linger: Duration::from_millis(1),
            },
            queue_capacity: 64,
            seed: 2023,
        }
    }
}

/// One open-loop sweep point's measurements.
#[derive(Clone, Debug)]
pub struct OpenLoopRow {
    pub offered_rps: f64,
    pub achieved_rps: f64,
    pub shards: usize,
    pub workers: usize,
    pub coalesce: bool,
    /// Arrivals admitted to a queue (tickets issued).
    pub accepted: usize,
    /// Arrivals refused at submit time (queue full / quota / deadline).
    pub shed: usize,
    pub shed_rate: f64,
    /// Admitted requests whose ticket came back with an error (worker-side
    /// deadline expiry, engine failure).  Counted, never propagated — and
    /// contributing NO latency sample.
    pub errors: usize,
    /// Successful responses backing the percentiles below: `accepted -
    /// errors`.  Sheds and errors are excluded from every latency figure.
    pub latency_samples: usize,
    /// Sojourn (queue wait + service) percentiles, milliseconds.
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub mean_wait_ms: f64,
    pub mean_service_ms: f64,
    /// M/M/c cross-check (single-shard, coalesce-off, uncongested points
    /// only; `None` elsewhere).
    pub utilisation: Option<f64>,
    pub predicted_wait_ms: Option<f64>,
    pub mmc_checked: bool,
}

/// Run the open-loop sweep.  Returns the rendered table and the
/// `poets-impute/bench-serve-load/v1` JSON document (archived as
/// `BENCH_serve_load.json`).  Fails if any uncongested single-shard point
/// disagrees with the M/M/c prediction beyond [`mmc::REL_TOLERANCE`]× +
/// [`mmc::ABS_TOLERANCE_SECONDS`].
pub fn run_open_loop(opts: &OpenLoopOpts) -> Result<(String, Json), String> {
    if opts.offered_rps.is_empty() || opts.shards.is_empty() {
        return Err("bench-serve --open-loop: need at least one offered rate and shard count".into());
    }
    if opts.offered_rps.iter().any(|&r| !(r > 0.0) || !r.is_finite()) {
        return Err("bench-serve --open-loop: offered rates must be finite and > 0".into());
    }
    if opts.requests == 0 || opts.targets_per_request == 0 || opts.workers == 0 {
        return Err("bench-serve --open-loop: requests, targets and workers must be >= 1".into());
    }

    // One panel per shard slot so the largest shard sweep sees spread
    // traffic; targets are pre-minted so arrival times measure the queue,
    // not panel generation.
    let registry = Arc::new(PanelRegistry::new());
    let n_panels = opts.shards.iter().copied().max().unwrap_or(1).max(1);
    let mut panels = Vec::with_capacity(n_panels);
    for i in 0..n_panels {
        let name = format!("open-loop-{i}");
        let cfg = PanelConfig {
            n_hap: opts.panel_hap,
            n_mark: opts.panel_mark,
            annot_ratio: opts.panel_annot,
            seed: opts.seed.wrapping_mul(1000).wrapping_add(i as u64),
            ..PanelConfig::default()
        };
        let panel = registry.register_synthetic(&name, &cfg);
        let targets = panel.synthetic_targets(opts.targets_per_request, 0x10AD + i as u64)?;
        panels.push((name, targets));
    }

    let mut table = Table::new(&[
        "offered", "shards", "coalesce", "accepted", "shed", "errors", "req/s", "p50", "p99",
        "p999", "wait", "mmc",
    ]);
    let mut rows = Vec::new();
    let mut violations = Vec::new();
    let mut point = 0u64;
    for &offered in &opts.offered_rps {
        for &shards in &opts.shards {
            for coalesce in [false, true] {
                point += 1;
                let row = open_loop_point(
                    &registry, opts, &panels, offered, shards, coalesce, point,
                    &mut violations,
                )?;
                table.row(vec![
                    format!("{:.0}/s", row.offered_rps),
                    row.shards.to_string(),
                    if row.coalesce { "on" } else { "off" }.into(),
                    row.accepted.to_string(),
                    format!("{} ({:.0}%)", row.shed, row.shed_rate * 100.0),
                    row.errors.to_string(),
                    format!("{:.1}", row.achieved_rps),
                    format!("{:.2}ms", row.p50_ms),
                    format!("{:.2}ms", row.p99_ms),
                    format!("{:.2}ms", row.p999_ms),
                    format!("{:.2}ms", row.mean_wait_ms),
                    match (row.mmc_checked, row.predicted_wait_ms) {
                        (true, Some(p)) => format!("{p:.2}ms ok"),
                        (false, Some(p)) => format!("{p:.2}ms -"),
                        _ => "-".into(),
                    },
                ]);
                rows.push(row);
            }
        }
    }
    if !violations.is_empty() {
        return Err(format!(
            "bench-serve --open-loop: measured waits disagree with M/M/c beyond tolerance:\n{}",
            violations.join("\n")
        ));
    }
    Ok((table.render(), to_load_json(opts, &rows)))
}

/// One (offered, shards, coalesce) point: fresh sharded service, Poisson
/// arrivals round-robined over the per-shard panels, all tickets drained.
#[allow(clippy::too_many_arguments)]
fn open_loop_point(
    registry: &Arc<PanelRegistry>,
    opts: &OpenLoopOpts,
    panels: &[(String, Vec<crate::model::panel::TargetHaplotype>)],
    offered: f64,
    shards: usize,
    coalesce: bool,
    point: u64,
    violations: &mut Vec<String>,
) -> Result<OpenLoopRow, String> {
    let policy = if coalesce {
        opts.coalesce
    } else {
        CoalescePolicy::off()
    };
    let cfg = ServeConfig::default()
        .workers(opts.workers)
        .coalesce(policy)
        .queue_capacity(opts.queue_capacity.max(1));
    let service = ShardedService::start(Arc::clone(registry), cfg, shards);

    // Poisson arrivals on an absolute schedule: sleep-until keeps the
    // offered rate honest even when submits momentarily lag.
    let mut rng = Rng::new(opts.seed ^ point.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let start = Instant::now();
    let mut next = start;
    let mut tickets = Vec::new();
    let mut shed = 0usize;
    for i in 0..opts.requests {
        let dt = -(1.0 - rng.f64()).ln() / offered;
        next += Duration::from_secs_f64(dt);
        let now = Instant::now();
        if next > now {
            thread::sleep(next - now);
        }
        let (name, targets) = &panels[i % panels.len()];
        match service.submit(ImputeRequest::new(
            name.clone(),
            opts.engine,
            targets.clone(),
        )) {
            Ok(t) => tickets.push(t),
            Err(_) => shed += 1, // open loop: arrivals never block
        }
    }
    let submit_span = start.elapsed().as_secs_f64().max(1e-9);

    let accepted = tickets.len();
    let mut errors = 0usize;
    let mut waits = Vec::with_capacity(tickets.len());
    let mut services = Vec::with_capacity(tickets.len());
    let mut sojourns = Vec::with_capacity(tickets.len());
    for t in tickets {
        match t.wait() {
            Ok(r) => {
                waits.push(r.queue_wait_seconds);
                services.push(r.report.host_seconds);
                sojourns.push(r.queue_wait_seconds + r.report.host_seconds);
            }
            // A per-request failure (worker-side deadline expiry, engine
            // error) is a data point, not a sweep abort — count it and move
            // on.  Errored requests contribute no latency sample, so the
            // percentiles below cover successful responses only.
            Err(_) => errors += 1,
        }
    }
    service.shutdown();

    let latency_samples = sojourns.len();
    let mean = |v: &[f64]| {
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    };
    let pct = |v: &[f64], p: f64| if v.is_empty() { 0.0 } else { percentile(v, p) * 1e3 };
    let mean_wait = mean(&waits);
    let mean_service = mean(&services);
    let arrival_rate = accepted as f64 / submit_span;

    // Cross-check against M/M/c only where the model is honest: one shard
    // (one queue), no coalescing (service times are per-request), nothing
    // shed or errored (no truncation bias — an errored request has no
    // service-time sample), enough samples, uncongested.
    let mut utilisation = None;
    let mut predicted_wait_ms = None;
    let mut mmc_checked = false;
    if shards == 1 && !coalesce && shed == 0 && errors == 0 && accepted >= 20 {
        if let Some(pred) = mmc::predict(opts.workers, arrival_rate, mean_service) {
            utilisation = Some(pred.utilisation);
            predicted_wait_ms = Some(pred.mean_wait_seconds * 1e3);
            if pred.utilisation <= 0.7 {
                mmc_checked = true;
                if !mmc::within_tolerance(mean_wait, pred.mean_wait_seconds) {
                    violations.push(format!(
                        "offered {offered:.0}/s: measured mean wait {:.3} ms vs M/M/{} \
                         prediction {:.3} ms (utilisation {:.2})",
                        mean_wait * 1e3,
                        opts.workers,
                        pred.mean_wait_seconds * 1e3,
                        pred.utilisation
                    ));
                }
            }
        }
    }

    Ok(OpenLoopRow {
        offered_rps: offered,
        achieved_rps: accepted as f64 / submit_span,
        shards,
        workers: opts.workers,
        coalesce,
        accepted,
        shed,
        shed_rate: shed as f64 / opts.requests.max(1) as f64,
        errors,
        latency_samples,
        p50_ms: pct(&sojourns, 50.0),
        p99_ms: pct(&sojourns, 99.0),
        p999_ms: pct(&sojourns, 99.9),
        mean_wait_ms: mean_wait * 1e3,
        mean_service_ms: mean_service * 1e3,
        utilisation,
        predicted_wait_ms,
        mmc_checked,
    })
}

fn to_load_json(opts: &OpenLoopOpts, rows: &[OpenLoopRow]) -> Json {
    let opt_num = |v: Option<f64>| v.map_or(Json::Null, Json::Num);
    let mut json_rows = Json::Arr(Vec::new());
    for r in rows {
        let mut j = Json::obj();
        j.set("offered_rps", r.offered_rps)
            .set("achieved_rps", r.achieved_rps)
            .set("shards", r.shards)
            .set("workers", r.workers)
            .set("coalesce", r.coalesce)
            .set("accepted", r.accepted)
            .set("shed", r.shed)
            .set("shed_rate", r.shed_rate)
            .set("errors", r.errors)
            .set("latency_samples", r.latency_samples)
            .set("p50_ms", r.p50_ms)
            .set("p99_ms", r.p99_ms)
            .set("p999_ms", r.p999_ms)
            .set("mean_wait_ms", r.mean_wait_ms)
            .set("mean_service_ms", r.mean_service_ms)
            .set("utilisation", opt_num(r.utilisation))
            .set("predicted_wait_ms", opt_num(r.predicted_wait_ms))
            .set("mmc_checked", r.mmc_checked);
        json_rows.push(j);
    }
    let mut run_config = Json::obj();
    run_config
        .set("engine", opts.engine.name())
        .set(
            "offered_rps",
            Json::Arr(opts.offered_rps.iter().map(|&r| Json::Num(r)).collect()),
        )
        .set(
            "shards",
            Json::Arr(opts.shards.iter().map(|&n| Json::Int(n as i64)).collect()),
        )
        .set("workers", opts.workers)
        .set("requests", opts.requests)
        .set("targets_per_request", opts.targets_per_request)
        .set("panel_hap", opts.panel_hap)
        .set("panel_mark", opts.panel_mark)
        .set("panel_annot", opts.panel_annot)
        .set("queue_capacity", opts.queue_capacity)
        .set("max_batch_targets", opts.coalesce.max_batch_targets)
        .set("linger_ms", opts.coalesce.max_linger.as_millis() as u64)
        .set("seed", opts.seed);

    let mut j = Json::obj();
    crate::util::provenance::stamp(&mut j, "poets-impute/bench-serve-load/v1", run_config);
    j.set("bench", "serve-open-loop")
        .set("engine", opts.engine.name())
        .set("rows", json_rows);
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_produces_rows_for_every_config() {
        let opts = BenchServeOpts {
            clients: vec![1, 2],
            workers: vec![1, 2],
            requests_per_client: 3,
            targets_per_request: 1,
            engine: EngineSpec::Rank1,
            panel: "synth:hap=8,mark=21,annot=0.2,seed=5".into(),
            coalesce: CoalescePolicy {
                max_batch_targets: 8,
                max_linger: Duration::from_millis(1),
            },
        };
        let (text, json) = run(&opts).unwrap();
        assert!(text.contains("req/s"));
        assert_eq!(
            json.get("schema").unwrap().as_str(),
            Some("poets-impute/bench-serve/v1")
        );
        // Provenance stamp: commit + reproducible sweep shape.
        assert!(json.get("git_commit").unwrap().as_str().is_some());
        let rc = json.get("run_config").unwrap();
        assert_eq!(
            rc.get("panel").unwrap().as_str(),
            Some("synth:hap=8,mark=21,annot=0.2,seed=5")
        );
        assert_eq!(rc.get("requests_per_client").unwrap().as_i64(), Some(3));
        let rows = json.get("rows").unwrap().as_arr().unwrap();
        // workers × clients × {off, on}.
        assert_eq!(rows.len(), 8);
        let worker_counts: std::collections::BTreeSet<i64> = rows
            .iter()
            .map(|r| r.get("workers").unwrap().as_i64().unwrap())
            .collect();
        assert_eq!(worker_counts.len(), 2, "baseline must cover >= 2 pool sizes");
        for r in rows {
            assert_eq!(r.get("requests").unwrap().as_i64(), Some(3 * r.get("clients").unwrap().as_i64().unwrap()));
            assert!(r.get("requests_per_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(r.get("p99_ms").unwrap().as_f64().unwrap()
                >= r.get("p50_ms").unwrap().as_f64().unwrap());
            assert!(r.get("mean_batch_width").unwrap().as_f64().unwrap() >= 1.0);
        }
    }

    #[test]
    fn degenerate_opts_are_rejected() {
        let no_requests = BenchServeOpts {
            requests_per_client: 0,
            ..BenchServeOpts::default()
        };
        assert!(run(&no_requests).is_err());
        let no_workers = BenchServeOpts {
            workers: Vec::new(),
            ..BenchServeOpts::default()
        };
        assert!(run(&no_workers).is_err());
    }

    #[test]
    fn open_loop_sweep_reports_per_point_and_passes_the_mmc_gate() {
        let opts = OpenLoopOpts {
            offered_rps: vec![200.0],
            shards: vec![1, 2],
            workers: 2,
            requests: 24,
            targets_per_request: 1,
            panel_hap: 8,
            panel_mark: 21,
            panel_annot: 0.2,
            seed: 7,
            ..OpenLoopOpts::default()
        };
        // The gate is part of the contract: a mismatch is an Err, so a
        // plain unwrap asserts measured-vs-M/M/c agreement.
        let (text, json) = run_open_loop(&opts).unwrap();
        assert!(text.contains("p999"));
        assert_eq!(
            json.get("schema").unwrap().as_str(),
            Some("poets-impute/bench-serve-load/v1")
        );
        assert!(json.get("git_commit").unwrap().as_str().is_some());
        let rc = json.get("run_config").unwrap();
        assert_eq!(rc.get("workers").unwrap().as_i64(), Some(2));
        assert_eq!(rc.get("offered_rps").unwrap().as_arr().unwrap().len(), 1);

        let rows = json.get("rows").unwrap().as_arr().unwrap();
        // offered × shards × {off, on}.
        assert_eq!(rows.len(), 4);
        for r in rows {
            let accepted = r.get("accepted").unwrap().as_i64().unwrap();
            let shed = r.get("shed").unwrap().as_i64().unwrap();
            assert_eq!(accepted + shed, 24, "every arrival is accounted for");
            // Errors are recorded per point; the percentile basis is
            // explicit: successes only.
            let errors = r.get("errors").unwrap().as_i64().unwrap();
            let samples = r.get("latency_samples").unwrap().as_i64().unwrap();
            assert_eq!(samples, accepted - errors, "percentiles cover successes only");
            assert_eq!(errors, 0, "healthy tiny sweep serves every admitted request");
            assert!(r.get("p999_ms").unwrap().as_f64().unwrap()
                >= r.get("p50_ms").unwrap().as_f64().unwrap());
            assert!(r.get("shed_rate").unwrap().as_f64().unwrap() >= 0.0);
            // Multi-shard and coalesced points never claim an M/M/c check.
            let sharded = r.get("shards").unwrap().as_i64().unwrap() > 1;
            let coalesced = r.get("coalesce").unwrap().as_bool().unwrap();
            if sharded || coalesced {
                assert_eq!(r.get("mmc_checked").unwrap().as_bool(), Some(false));
            }
        }
    }

    #[test]
    fn open_loop_degenerate_opts_are_rejected() {
        let no_rate = OpenLoopOpts {
            offered_rps: Vec::new(),
            ..OpenLoopOpts::default()
        };
        assert!(run_open_loop(&no_rate).is_err());
        let zero_rate = OpenLoopOpts {
            offered_rps: vec![0.0],
            ..OpenLoopOpts::default()
        };
        assert!(run_open_loop(&zero_rate).is_err());
        let no_workers = OpenLoopOpts {
            workers: 0,
            ..OpenLoopOpts::default()
        };
        assert!(run_open_loop(&no_workers).is_err());
    }
}
