//! `bench-serve` — a closed-loop load generator for the service.
//!
//! Sweeps worker counts × client counts × coalescing on/off against one
//! panel and engine.  Each simulated client is closed-loop (submit, block
//! for the answer, repeat), the classic service-benchmark shape: offered
//! load scales with client count and queueing shows up as latency rather
//! than unbounded backlog.  Per config the sweep reports throughput
//! (requests/s), latency percentiles (p50/p99) and the achieved mean
//! coalesce width — the numbers archived in `BENCH_serve.json` that the
//! panel-level wave-batching perf work must beat (see `ROADMAP.md`).

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::session::EngineSpec;
use crate::util::json::Json;
use crate::util::stats::percentile;
use crate::util::table::{Table, fmt_secs};

use super::queue::CoalescePolicy;
use super::{ImputeRequest, PanelRegistry, ServeConfig, Service};

/// Sweep shape.  Defaults are sized to finish in seconds on a laptop while
/// still showing the coalescing and pool-scaling effects.
#[derive(Clone, Debug)]
pub struct BenchServeOpts {
    /// Concurrent closed-loop clients (one sweep point per entry).
    pub clients: Vec<usize>,
    /// Service worker-pool sizes (one sweep point per entry; keep >= 2
    /// entries so the baseline records pool scaling).
    pub workers: Vec<usize>,
    /// Requests each client submits per sweep point.
    pub requests_per_client: usize,
    /// Targets per request.
    pub targets_per_request: usize,
    /// Compute plane under load.
    pub engine: EngineSpec,
    /// Panel spec every request hits (the multi-tenant hot-panel case).
    pub panel: String,
    /// Coalescing policy for the "on" half of the sweep.
    pub coalesce: CoalescePolicy,
}

impl Default for BenchServeOpts {
    fn default() -> Self {
        BenchServeOpts {
            clients: vec![1, 4, 8],
            workers: vec![1, 4],
            requests_per_client: 16,
            targets_per_request: 2,
            engine: EngineSpec::Rank1,
            panel: "synth:hap=16,mark=101,annot=0.1,seed=2023".into(),
            coalesce: CoalescePolicy {
                max_batch_targets: 16,
                max_linger: Duration::from_millis(1),
            },
        }
    }
}

/// One sweep point's measurements.
#[derive(Clone, Debug)]
pub struct BenchServeRow {
    pub workers: usize,
    pub clients: usize,
    pub coalesce: bool,
    pub requests: usize,
    pub wall_seconds: f64,
    pub requests_per_s: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_batch_width: f64,
    pub batches: u64,
}

/// Run the sweep.  Returns the rendered table and the
/// `poets-impute/bench-serve/v1` JSON document (the caller archives it as
/// `BENCH_serve.json`).
pub fn run(opts: &BenchServeOpts) -> Result<(String, Json), String> {
    if opts.clients.is_empty() || opts.workers.is_empty() {
        return Err("bench-serve: need at least one client and worker count".into());
    }
    if opts.requests_per_client == 0 || opts.targets_per_request == 0 {
        return Err("bench-serve: requests and targets per request must be >= 1".into());
    }
    let registry = Arc::new(PanelRegistry::new());
    // Resolve once up front: panel generation must not pollute the first
    // sweep point's latencies.
    registry.resolve(&opts.panel)?;

    let mut table = Table::new(&[
        "workers", "clients", "coalesce", "requests", "wall", "req/s", "p50", "p99",
        "mean width",
    ]);
    let mut rows = Vec::new();
    for &workers in &opts.workers {
        for &clients in &opts.clients {
            for coalesce in [false, true] {
                let row = sweep_point(&registry, opts, workers, clients, coalesce)?;
                table.row(vec![
                    row.workers.to_string(),
                    row.clients.to_string(),
                    if row.coalesce { "on" } else { "off" }.into(),
                    row.requests.to_string(),
                    fmt_secs(row.wall_seconds),
                    format!("{:.1}", row.requests_per_s),
                    format!("{:.2}ms", row.p50_ms),
                    format!("{:.2}ms", row.p99_ms),
                    format!("{:.2}", row.mean_batch_width),
                ]);
                rows.push(row);
            }
        }
    }
    Ok((table.render(), to_json(opts, &rows)))
}

/// One (workers, clients, coalesce) config: fresh service, closed-loop
/// clients with disjoint per-client target sets, merged latency stats.
fn sweep_point(
    registry: &Arc<PanelRegistry>,
    opts: &BenchServeOpts,
    workers: usize,
    clients: usize,
    coalesce: bool,
) -> Result<BenchServeRow, String> {
    let policy = if coalesce {
        opts.coalesce
    } else {
        CoalescePolicy::off()
    };
    let cfg = ServeConfig::default()
        .workers(workers)
        .coalesce(policy)
        .queue_capacity((clients * opts.requests_per_client).max(16));
    let service = Service::start(Arc::clone(registry), cfg);

    // Disjoint per-client targets, minted outside the timed section.
    let panel = registry.resolve(&opts.panel)?;
    let per_client: Vec<_> = (0..clients)
        .map(|c| panel.synthetic_targets(opts.targets_per_request, 0x10AD + c as u64))
        .collect::<Result<_, _>>()?;

    let start = Instant::now();
    let latencies: Vec<Vec<f64>> = thread::scope(|s| {
        let handles: Vec<_> = per_client
            .into_iter()
            .map(|targets| {
                let service = &service;
                let panel_name = opts.panel.clone();
                let engine = opts.engine;
                let n = opts.requests_per_client;
                s.spawn(move || -> Result<Vec<f64>, String> {
                    let mut lats = Vec::with_capacity(n);
                    for _ in 0..n {
                        let t0 = Instant::now();
                        service.submit_wait(ImputeRequest {
                            panel: panel_name.clone(),
                            engine,
                            targets: targets.clone().into(),
                        })?;
                        lats.push(t0.elapsed().as_secs_f64());
                    }
                    Ok(lats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("bench client panicked"))
            .collect::<Result<Vec<Vec<f64>>, String>>()
    })?;
    let wall_seconds = start.elapsed().as_secs_f64();
    let stats = service.shutdown();

    let all: Vec<f64> = latencies.into_iter().flatten().collect();
    let requests = all.len();
    Ok(BenchServeRow {
        workers,
        clients,
        coalesce,
        requests,
        wall_seconds,
        requests_per_s: requests as f64 / wall_seconds.max(1e-12),
        p50_ms: percentile(&all, 50.0) * 1e3,
        p99_ms: percentile(&all, 99.0) * 1e3,
        mean_batch_width: stats.mean_batch_width(),
        batches: stats.batches,
    })
}

fn to_json(opts: &BenchServeOpts, rows: &[BenchServeRow]) -> Json {
    let mut json_rows = Json::Arr(Vec::new());
    for r in rows {
        let mut j = Json::obj();
        j.set("workers", r.workers)
            .set("clients", r.clients)
            .set("coalesce", r.coalesce)
            .set("requests", r.requests)
            .set("wall_seconds", r.wall_seconds)
            .set("requests_per_s", r.requests_per_s)
            .set("p50_ms", r.p50_ms)
            .set("p99_ms", r.p99_ms)
            .set("mean_batch_width", r.mean_batch_width)
            .set("batches", r.batches);
        json_rows.push(j);
    }
    let mut run_config = Json::obj();
    run_config
        .set("engine", opts.engine.name())
        .set("panel", opts.panel.as_str())
        .set(
            "workers",
            Json::Arr(opts.workers.iter().map(|&n| Json::Int(n as i64)).collect()),
        )
        .set(
            "clients",
            Json::Arr(opts.clients.iter().map(|&n| Json::Int(n as i64)).collect()),
        )
        .set("requests_per_client", opts.requests_per_client)
        .set("targets_per_request", opts.targets_per_request)
        .set("max_batch_targets", opts.coalesce.max_batch_targets)
        .set("linger_ms", opts.coalesce.max_linger.as_millis() as u64);

    let mut j = Json::obj();
    // Provenance (schema / git_commit / run_config): a tracked artifact
    // must name the commit and sweep shape that produced its numbers.
    crate::util::provenance::stamp(&mut j, "poets-impute/bench-serve/v1", run_config);
    j.set("bench", "serve")
        .set("engine", opts.engine.name())
        .set("panel", opts.panel.as_str())
        .set("requests_per_client", opts.requests_per_client)
        .set("targets_per_request", opts.targets_per_request)
        .set("rows", json_rows);
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_produces_rows_for_every_config() {
        let opts = BenchServeOpts {
            clients: vec![1, 2],
            workers: vec![1, 2],
            requests_per_client: 3,
            targets_per_request: 1,
            engine: EngineSpec::Rank1,
            panel: "synth:hap=8,mark=21,annot=0.2,seed=5".into(),
            coalesce: CoalescePolicy {
                max_batch_targets: 8,
                max_linger: Duration::from_millis(1),
            },
        };
        let (text, json) = run(&opts).unwrap();
        assert!(text.contains("req/s"));
        assert_eq!(
            json.get("schema").unwrap().as_str(),
            Some("poets-impute/bench-serve/v1")
        );
        // Provenance stamp: commit + reproducible sweep shape.
        assert!(json.get("git_commit").unwrap().as_str().is_some());
        let rc = json.get("run_config").unwrap();
        assert_eq!(
            rc.get("panel").unwrap().as_str(),
            Some("synth:hap=8,mark=21,annot=0.2,seed=5")
        );
        assert_eq!(rc.get("requests_per_client").unwrap().as_i64(), Some(3));
        let rows = json.get("rows").unwrap().as_arr().unwrap();
        // workers × clients × {off, on}.
        assert_eq!(rows.len(), 8);
        let worker_counts: std::collections::BTreeSet<i64> = rows
            .iter()
            .map(|r| r.get("workers").unwrap().as_i64().unwrap())
            .collect();
        assert_eq!(worker_counts.len(), 2, "baseline must cover >= 2 pool sizes");
        for r in rows {
            assert_eq!(r.get("requests").unwrap().as_i64(), Some(3 * r.get("clients").unwrap().as_i64().unwrap()));
            assert!(r.get("requests_per_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(r.get("p99_ms").unwrap().as_f64().unwrap()
                >= r.get("p50_ms").unwrap().as_f64().unwrap());
            assert!(r.get("mean_batch_width").unwrap().as_f64().unwrap() >= 1.0);
        }
    }

    #[test]
    fn degenerate_opts_are_rejected() {
        let no_requests = BenchServeOpts {
            requests_per_client: 0,
            ..BenchServeOpts::default()
        };
        assert!(run(&no_requests).is_err());
        let no_workers = BenchServeOpts {
            workers: Vec::new(),
            ..BenchServeOpts::default()
        };
        assert!(run(&no_workers).is_err());
    }
}
