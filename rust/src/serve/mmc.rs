//! `serve::mmc` — M/M/c queueing predictions for the serve plane.
//!
//! The open-loop load bench (`bench-serve --open-loop`) measures queue
//! waits under Poisson arrivals; this module predicts them from first
//! principles so measured behaviour can be cross-checked against an
//! analytic model — the serve-plane analogue of the Fig-12 hardware
//! calibration.  A shard with `c` workers fed Poisson arrivals at rate λ
//! with mean service time E[S] = 1/μ is modelled as M/M/c:
//!
//! * offered load (Erlang) `a = λ/μ`, utilisation `ρ = a/c`;
//! * probability an arrival waits: the Erlang-C formula, computed via the
//!   numerically-stable Erlang-B recurrence `B(0) = 1`,
//!   `B(k) = a·B(k−1) / (k + a·B(k−1))`, then
//!   `C = B(c) / (1 − ρ·(1 − B(c)))`;
//! * mean queue wait `Wq = C / (c·μ − λ)`.
//!
//! The model's service times are exponential; the serve plane's are
//! near-deterministic per (panel, engine), which makes M/M/c an *upper*
//! bound tendency on waits (M/D/c waits are about half M/M/c at high ρ).
//! The agreement gate ([`within_tolerance`]) is therefore deliberately
//! loose — a factor of [`REL_TOLERANCE`] plus an absolute
//! [`ABS_TOLERANCE_SECONDS`] floor for scheduler noise — and the bench
//! only asserts it in the uncongested regime (ρ ≤ 0.7, no shedding),
//! where both models agree that waits are small.

/// Multiplicative slack for measured-vs-predicted agreement (either
/// direction): service times are not exponential and the host scheduler is
/// not a Poisson server.
pub const REL_TOLERANCE: f64 = 3.0;

/// Absolute slack (seconds) under which measured and predicted waits are
/// always considered to agree — scheduler wakeup latency alone contributes
/// milliseconds on a busy CI host.
pub const ABS_TOLERANCE_SECONDS: f64 = 0.010;

/// What M/M/c says about a shard at one operating point.
#[derive(Clone, Copy, Debug)]
pub struct MmcPrediction {
    /// Server utilisation ρ = λ/(c·μ).
    pub utilisation: f64,
    /// Erlang-C probability that an arrival has to queue.
    pub p_wait: f64,
    /// Mean queue wait Wq (seconds) — time from arrival to service start.
    pub mean_wait_seconds: f64,
}

/// Erlang-B blocking probability via the standard recurrence (stable for
/// any offered load `a >= 0`).
pub fn erlang_b(servers: usize, a: f64) -> f64 {
    let mut b = 1.0;
    for k in 1..=servers {
        b = a * b / (k as f64 + a * b);
    }
    b
}

/// Erlang-C waiting probability for `servers` servers at offered load `a`
/// Erlangs.  Meaningful for ρ = a/servers < 1 (clamped to 1.0 at or past
/// saturation: every arrival waits).
pub fn erlang_c(servers: usize, a: f64) -> f64 {
    let c = servers as f64;
    if a <= 0.0 {
        return 0.0;
    }
    let rho = a / c;
    if rho >= 1.0 {
        return 1.0;
    }
    let b = erlang_b(servers, a);
    b / (1.0 - rho * (1.0 - b))
}

/// Predict the M/M/c operating point for `servers` workers at arrival rate
/// `arrival_rate` (req/s) and mean service time `mean_service_seconds`.
/// `None` when the inputs are degenerate or the queue is unstable (ρ ≥ 1 —
/// waits diverge; the measured system sheds instead).
pub fn predict(
    servers: usize,
    arrival_rate: f64,
    mean_service_seconds: f64,
) -> Option<MmcPrediction> {
    if servers == 0
        || !arrival_rate.is_finite()
        || !mean_service_seconds.is_finite()
        || arrival_rate <= 0.0
        || mean_service_seconds <= 0.0
    {
        return None;
    }
    let c = servers as f64;
    let a = arrival_rate * mean_service_seconds;
    let rho = a / c;
    if rho >= 1.0 {
        return None;
    }
    let p_wait = erlang_c(servers, a);
    let mu = 1.0 / mean_service_seconds;
    let mean_wait_seconds = p_wait / (c * mu - arrival_rate);
    Some(MmcPrediction {
        utilisation: rho,
        p_wait,
        mean_wait_seconds,
    })
}

/// The bench gate: do a measured and a predicted mean wait agree, given
/// the documented slack?  Symmetric — each must be within
/// [`REL_TOLERANCE`]× of the other plus the absolute floor.
pub fn within_tolerance(measured_seconds: f64, predicted_seconds: f64) -> bool {
    if !measured_seconds.is_finite() || !predicted_seconds.is_finite() {
        return false;
    }
    let m = measured_seconds.max(0.0);
    let p = predicted_seconds.max(0.0);
    m <= REL_TOLERANCE * p + ABS_TOLERANCE_SECONDS
        && p <= REL_TOLERANCE * m + ABS_TOLERANCE_SECONDS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_server_reduces_to_mm1_closed_forms() {
        // M/M/1: p_wait = rho, Wq = rho / (mu - lambda).
        for &(lambda, mu) in &[(0.5, 1.0), (2.0, 10.0), (7.0, 8.0)] {
            let rho: f64 = lambda / mu;
            let pred = predict(1, lambda, 1.0 / mu).unwrap();
            assert!((pred.utilisation - rho).abs() < 1e-12);
            assert!((pred.p_wait - rho).abs() < 1e-12, "Erlang C(1, a) must be rho");
            let wq = rho / (mu - lambda);
            assert!(
                (pred.mean_wait_seconds - wq).abs() < 1e-12,
                "Wq {} vs closed form {wq}",
                pred.mean_wait_seconds
            );
        }
    }

    #[test]
    fn erlang_b_and_c_known_values() {
        // B(1, a) = a / (1 + a).
        assert!((erlang_b(1, 0.5) - 1.0 / 3.0).abs() < 1e-12);
        // B(2, 1) = (1/2) / (2 + 1/2)... via recurrence: b1 = 1/2,
        // b2 = 1*b1 / (2 + 1*b1) = 0.5/2.5 = 0.2.
        assert!((erlang_b(2, 1.0) - 0.2).abs() < 1e-12);
        // C(2, 1): rho = 0.5 -> C = 0.2 / (1 - 0.5*0.8) = 1/3.
        assert!((erlang_c(2, 1.0) - 1.0 / 3.0).abs() < 1e-12);
        // No load, no waiting; saturation, everyone waits.
        assert_eq!(erlang_c(4, 0.0), 0.0);
        assert_eq!(erlang_c(2, 2.0), 1.0);
        assert_eq!(erlang_c(2, 5.0), 1.0);
    }

    #[test]
    fn more_servers_means_less_waiting_at_fixed_utilisation() {
        // Classic pooling effect: at rho = 0.7, Wq shrinks as c grows.
        let mean_service = 0.010;
        let mut last = f64::MAX;
        for c in [1usize, 2, 4, 8] {
            let lambda = 0.7 * c as f64 / mean_service;
            let pred = predict(c, lambda, mean_service).unwrap();
            assert!((pred.utilisation - 0.7).abs() < 1e-12);
            assert!(
                pred.mean_wait_seconds < last,
                "Wq must fall with pooling (c={c})"
            );
            last = pred.mean_wait_seconds;
        }
    }

    #[test]
    fn degenerate_and_unstable_inputs_yield_none() {
        assert!(predict(0, 1.0, 0.1).is_none());
        assert!(predict(2, 0.0, 0.1).is_none());
        assert!(predict(2, 1.0, 0.0).is_none());
        assert!(predict(2, f64::NAN, 0.1).is_none());
        // rho >= 1: unstable.
        assert!(predict(2, 200.0, 0.01).is_none());
        assert!(predict(2, 201.0, 0.01).is_none());
    }

    #[test]
    fn tolerance_gate_is_symmetric_with_absolute_floor() {
        // Both tiny: always agree.
        assert!(within_tolerance(0.0, 0.002));
        assert!(within_tolerance(0.002, 0.0));
        // Within 3x of each other: agree.
        assert!(within_tolerance(0.030, 0.015));
        assert!(within_tolerance(0.015, 0.030));
        // Far apart beyond floor + factor: disagree, both directions.
        assert!(!within_tolerance(0.500, 0.050));
        assert!(!within_tolerance(0.050, 0.500));
        assert!(!within_tolerance(f64::NAN, 0.1));
    }
}
