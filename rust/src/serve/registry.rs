//! The panel registry: named reference panels loaded once, cached behind
//! `Arc`, and handed out as shared [`Workload`]s.
//!
//! Panels are the heavy shared state of a multi-tenant imputation service —
//! a genuine panel is hundreds of MiB, so every concurrent request against
//! the same panel must share one in-memory copy.  The registry owns that
//! copy: [`PanelRegistry::resolve`] returns an `Arc`-shared
//! [`RegisteredPanel`], and [`RegisteredPanel::workload`] assembles a request
//! workload around the shared handle without copying panel data
//! ([`Workload::from_shared`]).
//!
//! Ways for a panel to enter the registry:
//!
//! * **Explicit registration** ([`PanelRegistry::register`]) — the embedding
//!   application loads a cohort panel and names it.  Registered panels are
//!   **pinned**: the capacity bound below never evicts them.
//! * **Spec resolution** — a panel name with a recognised prefix is loaded
//!   on first use and cached under that exact string:
//!   - `synth:hap=H,mark=M[,maf=F][,annot=R][,seed=S]` — generated with the
//!     paper's §6.2 recipe (keeps the `serve`/`bench-serve` CLI
//!     self-contained and request lines reproducible);
//!   - `vcf:<path>` — ingested through [`crate::genomics::vcf`] (bi-allelic
//!     phased sites, per-site metadata retained);
//!   - `packed:<path>` — a bit-packed `.ppnl` file written by
//!     `poets-impute panel ingest` ([`crate::genomics::packed`]).
//!
//!   File-backed specs read whatever path the request names, so expose the
//!   serve frontends only to clients you would hand shell access to the
//!   panel directory anyway; loading failures (missing file, corrupt
//!   payload, malformed VCF) are recoverable errors that serve reports
//!   in-band, never worker panics.
//!
//! Spec-resolved panels are cached with **least-recently-resolved
//! eviction**: at most [`PanelRegistry::with_capacity`] unpinned panels
//! stay resident (default [`DEFAULT_SPEC_CAPACITY`]), so a stream of
//! distinct specs cannot grow the cache without bound.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::genomics::packed::PackedPanel;
use crate::genomics::vcf::{self, Site};
use crate::model::panel::{ReferencePanel, TargetHaplotype};
use crate::session::Workload;
use crate::util::rng::Rng;
use crate::workload::panelgen::{PanelConfig, TargetCase, generate_panel, generate_targets};

/// A panel held by the registry: the shared data plus (when synthetic) the
/// generation recipe, which lets the serve CLI mint matching targets and the
/// per-request report record provenance, and (when file-backed) the VCF
/// site metadata.
#[derive(Debug)]
pub struct RegisteredPanel {
    name: String,
    panel: Arc<ReferencePanel>,
    recipe: Option<PanelConfig>,
    sites: Option<Arc<Vec<Site>>>,
    /// Cap on `count * n_mark` for minted targets, inherited from the
    /// registry that created this panel (`usize::MAX` for unbounded
    /// registries).
    mint_cap: usize,
}

impl RegisteredPanel {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn panel(&self) -> &ReferencePanel {
        &self.panel
    }

    /// Shared handle to the panel data (cheap clone).
    pub fn panel_arc(&self) -> Arc<ReferencePanel> {
        Arc::clone(&self.panel)
    }

    /// Generation recipe, when the panel is synthetic.
    pub fn recipe(&self) -> Option<&PanelConfig> {
        self.recipe.as_ref()
    }

    /// Per-site metadata (CHROM/POS/ID, allele frequency), when the panel
    /// came from a VCF or a `.ppnl` that carried it.
    pub fn sites(&self) -> Option<&[Site]> {
        self.sites.as_deref().map(Vec::as_slice)
    }

    /// Assemble a request workload around the shared panel (no panel copy).
    pub fn workload(&self, targets: Vec<TargetHaplotype>) -> Result<Workload, String> {
        Workload::from_shared(self.panel_arc(), targets)
    }

    /// Mint `count` masked targets from the panel's own recipe (synthetic
    /// panels only) — how serve clients without real cohort data, the CI
    /// smoke test and the load generator obtain valid request payloads.
    /// Distinct `seed`s give disjoint target sets.  Like the spec parser,
    /// this caps the total allocation (`count * n_mark`) because the count
    /// arrives from untrusted request lines.
    pub fn synthetic_targets(
        &self,
        count: usize,
        seed: u64,
    ) -> Result<Vec<TargetHaplotype>, String> {
        let recipe = self
            .recipe
            .ok_or_else(|| format!("panel {:?} has no synthetic recipe", self.name))?;
        if count.saturating_mul(self.panel.n_mark()) > self.mint_cap {
            return Err(format!(
                "{count} synthetic targets x {} markers exceeds the service cap \
                 of {} observations",
                self.panel.n_mark(),
                self.mint_cap
            ));
        }
        let mut rng = Rng::new(seed ^ recipe.seed.rotate_left(17) ^ 0x5EED_7A26);
        Ok(generate_targets(&self.panel, &recipe, count, &mut rng)
            .into_iter()
            .map(|case| case.masked)
            .collect())
    }

    /// Mint `count` Li & Stephens mosaic targets from the panel itself,
    /// masked to an `annot_ratio` grid, **truth retained** — works for any
    /// panel (file-backed included): the mosaic process only needs the
    /// panel's haplotypes and genetic distances.  This is the paper's
    /// generative model, so accuracy scored against the retained truth is
    /// meaningful.  Deterministic in `seed`; capped like
    /// [`RegisteredPanel::synthetic_targets`].
    pub fn mosaic_targets(
        &self,
        count: usize,
        annot_ratio: f64,
        seed: u64,
    ) -> Result<Vec<TargetCase>, String> {
        if !(annot_ratio > 0.0 && annot_ratio <= 1.0) {
            return Err(format!("annot_ratio {annot_ratio} must be in (0, 1]"));
        }
        if count.saturating_mul(self.panel.n_mark()) > self.mint_cap {
            return Err(format!(
                "{count} mosaic targets x {} markers exceeds the service cap \
                 of {} observations",
                self.panel.n_mark(),
                self.mint_cap
            ));
        }
        let cfg = PanelConfig {
            n_hap: self.panel.n_hap(),
            n_mark: self.panel.n_mark(),
            annot_ratio,
            seed,
            ..PanelConfig::default()
        };
        let mut rng = Rng::new(seed.rotate_left(11) ^ 0x7A26_5EED);
        Ok(generate_targets(&self.panel, &cfg, count, &mut rng))
    }

    /// Mint masked request targets from whatever this panel can offer: the
    /// synthetic recipe when there is one, otherwise mosaic targets on a
    /// default 1-in-10 annotation grid — so `"synth_targets"` request lines
    /// work against `vcf:`/`packed:` panels too.
    pub fn minted_targets(
        &self,
        count: usize,
        seed: u64,
    ) -> Result<Vec<TargetHaplotype>, String> {
        if self.recipe.is_some() {
            self.synthetic_targets(count, seed)
        } else {
            Ok(self
                .mosaic_targets(count, DEFAULT_MINT_ANNOT_RATIO, seed)?
                .into_iter()
                .map(|case| case.masked)
                .collect())
        }
    }
}

/// One cache slot: the shared panel plus its eviction bookkeeping.
struct Entry {
    panel: Arc<RegisteredPanel>,
    /// Explicitly registered panels are never evicted.
    pinned: bool,
    /// Tick of the most recent resolve/insert (the LRU ordering key).
    last_used: u64,
}

#[derive(Default)]
struct RegistryState {
    entries: HashMap<String, Entry>,
    tick: u64,
}

/// Thread-safe name → panel cache.  `resolve` is what the serve workers call
/// on every coalesced batch; hits are one mutex lock + one `Arc` clone.
/// Spec-resolved entries are bounded (least-recently-resolved eviction);
/// registered panels are pinned and do not count against the bound.
///
/// Two admission policies, both per-registry:
///
/// * `capacity` — how many spec-resolved panels stay resident;
/// * `state_cap` — the largest `hap * mark` a spec may load (and the cap on
///   minted-target allocations).  The default suits serve frontends, where
///   specs arrive on untrusted request lines; trusted embedders loading
///   chromosome-scale panels (the CLI) use [`PanelRegistry::unbounded`].
pub struct PanelRegistry {
    state: Mutex<RegistryState>,
    capacity: usize,
    state_cap: usize,
}

/// Default bound on resident spec-resolved panels.
pub const DEFAULT_SPEC_CAPACITY: usize = 32;

/// Annotation grid used when minting targets for panels without a synthetic
/// recipe (see [`RegisteredPanel::minted_targets`]).
pub const DEFAULT_MINT_ANNOT_RATIO: f64 = 0.1;

impl Default for PanelRegistry {
    fn default() -> Self {
        PanelRegistry::with_caps(DEFAULT_SPEC_CAPACITY, MAX_PANEL_STATES)
    }
}

impl PanelRegistry {
    pub fn new() -> PanelRegistry {
        PanelRegistry::default()
    }

    /// A registry keeping at most `capacity` spec-resolved panels resident
    /// (pinned registered panels are exempt and uncounted).
    pub fn with_capacity(capacity: usize) -> PanelRegistry {
        PanelRegistry::with_caps(capacity, MAX_PANEL_STATES)
    }

    /// A registry for trusted callers: no panel-size or minted-target cap
    /// (cache bound still applies).  This is what `impute --panel` and
    /// `panel info` use — a chromosome-scale `.ppnl` is the point of the
    /// windowed pipeline, not an attack.
    pub fn unbounded() -> PanelRegistry {
        PanelRegistry::with_caps(DEFAULT_SPEC_CAPACITY, usize::MAX)
    }

    /// Full control over both bounds (`state_cap` = max `hap * mark` a spec
    /// may load, and the minted-target observation cap).
    pub fn with_caps(capacity: usize, state_cap: usize) -> PanelRegistry {
        PanelRegistry {
            state: Mutex::new(RegistryState::default()),
            capacity: capacity.max(1),
            state_cap,
        }
    }

    /// Register a pre-loaded panel under `name` (replacing any previous
    /// holder of the name).  Returns the shared handle.  Registered panels
    /// are pinned: eviction never touches them.
    pub fn register(&self, name: &str, panel: ReferencePanel) -> Arc<RegisteredPanel> {
        self.insert(RegisteredPanel {
            name: name.to_string(),
            panel: Arc::new(panel),
            recipe: None,
            sites: None,
            mint_cap: self.state_cap,
        })
    }

    /// Register a synthetic panel under `name`, generated from `cfg` now.
    /// The recipe is retained so `synthetic_targets` works.
    pub fn register_synthetic(&self, name: &str, cfg: &PanelConfig) -> Arc<RegisteredPanel> {
        self.insert(RegisteredPanel {
            name: name.to_string(),
            panel: Arc::new(generate_panel(cfg)),
            recipe: Some(*cfg),
            sites: None,
            mint_cap: self.state_cap,
        })
    }

    fn insert(&self, panel: RegisteredPanel) -> Arc<RegisteredPanel> {
        let shared = Arc::new(panel);
        let mut st = self.state.lock().expect(POISONED);
        st.tick += 1;
        let tick = st.tick;
        st.entries.insert(
            shared.name.clone(),
            Entry {
                panel: Arc::clone(&shared),
                pinned: true,
                last_used: tick,
            },
        );
        shared
    }

    /// Look up `name`, loading recognised specs (`synth:` / `vcf:` /
    /// `packed:`) on first use.
    ///
    /// The cache key is the exact spec string, so two spellings of the same
    /// recipe (`synth:hap=8,mark=21` vs `synth:mark=21,hap=8`) cache
    /// separately — canonicalise spellings client-side if that matters.
    /// Loading happens **outside** the lock: a request naming a slow or
    /// blocking path (NFS, a FIFO) stalls only its own resolve, never the
    /// whole registry.  The price is that concurrent first requests for the
    /// same spec may both load it; the first insert wins and later loaders
    /// adopt the cached copy, so callers still share one panel.
    pub fn resolve(&self, name: &str) -> Result<Arc<RegisteredPanel>, String> {
        {
            let mut st = self.state.lock().expect(POISONED);
            st.tick += 1;
            let tick = st.tick;
            if let Some(e) = st.entries.get_mut(name) {
                e.last_used = tick;
                return Ok(Arc::clone(&e.panel));
            }
        }
        let loaded = Arc::new(load_spec(name, self.state_cap)?);
        let mut st = self.state.lock().expect(POISONED);
        st.tick += 1;
        let tick = st.tick;
        if let Some(e) = st.entries.get_mut(name) {
            // A racing resolve beat us to the insert: share its copy and
            // drop ours.
            e.last_used = tick;
            return Ok(Arc::clone(&e.panel));
        }
        st.entries.insert(
            name.to_string(),
            Entry {
                panel: Arc::clone(&loaded),
                pinned: false,
                last_used: tick,
            },
        );
        self.evict_over_capacity(&mut st);
        Ok(loaded)
    }

    /// Drop least-recently-resolved unpinned entries until the bound holds.
    /// The entry just inserted carries the newest tick, so it survives.
    fn evict_over_capacity(&self, st: &mut RegistryState) {
        loop {
            let unpinned = st.entries.values().filter(|e| !e.pinned).count();
            if unpinned <= self.capacity {
                return;
            }
            let victim = st
                .entries
                .iter()
                .filter(|(_, e)| !e.pinned)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("unpinned count > 0");
            st.entries.remove(&victim);
        }
    }

    /// Names currently cached (sorted, for `info`-style listings).
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .state
            .lock()
            .expect(POISONED)
            .entries
            .keys()
            .cloned()
            .collect();
        names.sort();
        names
    }

    pub fn len(&self) -> usize {
        self.state.lock().expect(POISONED).entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

const POISONED: &str = "panel registry poisoned";

/// Load a spec-named panel: dispatch on the prefix.  `state_cap` is the
/// owning registry's admission bound on `hap * mark`.
fn load_spec(name: &str, state_cap: usize) -> Result<RegisteredPanel, String> {
    if let Some(spec) = name.strip_prefix("synth:") {
        let cfg = parse_synth_spec(spec, state_cap)?;
        return Ok(RegisteredPanel {
            name: name.to_string(),
            panel: Arc::new(generate_panel(&cfg)),
            recipe: Some(cfg),
            sites: None,
            mint_cap: state_cap,
        });
    }
    if let Some(path) = name.strip_prefix("vcf:") {
        // Pre-admission by file size: every haplotype-site costs >= 2 bytes
        // of GT text, so a file bigger than 16 bytes/state is over any cap
        // with enormous slack — rejected before the read, so cheap request
        // lines cannot repeatedly trigger multi-GB parses.
        if state_cap != usize::MAX {
            let bytes = std::fs::metadata(path)
                .map_err(|e| format!("cannot read {path}: {e}"))?
                .len();
            let budget = (state_cap as u64).saturating_mul(16).max(64 << 20);
            if bytes > budget {
                return Err(format!(
                    "{path} is {bytes} bytes, over this registry's admission budget \
                     of {budget} (the panel cannot fit the {state_cap}-state cap)"
                ));
            }
        }
        let v = vcf::load(path)?;
        check_loaded_size(v.panel.n_hap(), v.panel.n_mark(), state_cap)?;
        return Ok(RegisteredPanel {
            name: name.to_string(),
            panel: Arc::new(v.panel),
            recipe: None,
            sites: Some(Arc::new(v.sites)),
            mint_cap: state_cap,
        });
    }
    if let Some(path) = name.strip_prefix("packed:") {
        // Pre-admission from the 32-byte header: reject over-cap panels
        // before reading, checksumming and unpacking the whole file.
        let (n_hap, n_mark) = PackedPanel::peek_shape(path)?;
        check_loaded_size(n_hap, n_mark, state_cap)?;
        // And by file size: a file vastly larger than its claimed shape can
        // justify (distances + bit rows + a generous 1 KiB/site metadata
        // allowance) is garbage-padded — reject before `read` loads it all.
        if state_cap != usize::MAX {
            let bytes = std::fs::metadata(path)
                .map_err(|e| format!("cannot read {path}: {e}"))?
                .len();
            let budget = 40u64
                .saturating_add(n_mark as u64 * 8)
                .saturating_add(n_hap as u64 * n_mark.div_ceil(8) as u64)
                .saturating_add(n_mark as u64 * 1024)
                .max(1 << 20);
            if bytes > budget {
                return Err(format!(
                    "{path} is {bytes} bytes but its header claims a \
                     {n_hap}x{n_mark} panel (budget {budget} bytes) — refusing to load"
                ));
            }
        }
        let packed = PackedPanel::read(path)?;
        check_loaded_size(packed.n_hap(), packed.n_mark(), state_cap)?;
        let sites = packed.sites().map(|s| Arc::new(s.to_vec()));
        return Ok(RegisteredPanel {
            name: name.to_string(),
            panel: Arc::new(packed.to_panel()),
            recipe: None,
            sites,
            mint_cap: state_cap,
        });
    }
    Err(format!(
        "unknown panel {name:?} (register it, or use a synth:hap=..,mark=.. / \
         vcf:<path> / packed:<path> spec)"
    ))
}

/// File-backed panels answer to the same admission cap as synth specs: a
/// request naming a huge file must fail cleanly, not balloon the registry.
fn check_loaded_size(n_hap: usize, n_mark: usize, state_cap: usize) -> Result<(), String> {
    if n_hap.saturating_mul(n_mark) > state_cap {
        return Err(format!(
            "panel has {} states ({n_hap} x {n_mark}), over the service cap of \
             {state_cap}",
            n_hap.saturating_mul(n_mark)
        ));
    }
    Ok(())
}

/// Parse the body of a `synth:` panel name: comma-separated `key=value`
/// pairs.  `hap` and `mark` are required; `maf`, `annot`, `seed` default to
/// the paper's recipe (0.05, 0.1, 0).
fn parse_synth_spec(spec: &str, state_cap: usize) -> Result<PanelConfig, String> {
    let mut cfg = PanelConfig {
        annot_ratio: 0.1,
        ..PanelConfig::default()
    };
    let (mut saw_hap, mut saw_mark) = (false, false);
    for field in spec.split(',') {
        let field = field.trim();
        if field.is_empty() {
            continue;
        }
        let Some((key, value)) = field.split_once('=') else {
            return Err(format!("synth spec field {field:?} is not key=value"));
        };
        fn parse_field<T: std::str::FromStr>(key: &str, value: &str) -> Result<T, String> {
            value
                .trim()
                .parse()
                .map_err(|_| format!("synth spec: cannot parse {key}={value:?}"))
        }
        match key.trim() {
            "hap" => {
                cfg.n_hap = parse_field(key, value)?;
                saw_hap = true;
            }
            "mark" => {
                cfg.n_mark = parse_field(key, value)?;
                saw_mark = true;
            }
            "maf" => cfg.maf = parse_field(key, value)?,
            "annot" => cfg.annot_ratio = parse_field(key, value)?,
            "seed" => cfg.seed = parse_field(key, value)?,
            other => {
                return Err(format!(
                    "synth spec: unknown key {other:?} (expected hap|mark|maf|annot|seed)"
                ));
            }
        }
    }
    if !saw_hap || !saw_mark {
        return Err("synth spec needs at least hap=.. and mark=..".into());
    }
    // Specs arrive from untrusted request lines: every range that would
    // trip an assert (and panic the service) deeper in panelgen must be
    // rejected here with a recoverable error instead.
    if cfg.n_hap < 2 || cfg.n_mark < 2 {
        return Err("synth spec: hap and mark must be >= 2".into());
    }
    if cfg.n_hap.saturating_mul(cfg.n_mark) > state_cap {
        return Err(format!(
            "synth spec: hap*mark = {} exceeds the service cap of {state_cap} states",
            cfg.n_hap.saturating_mul(cfg.n_mark)
        ));
    }
    if !(cfg.maf > 0.0 && cfg.maf <= 0.5) {
        return Err("synth spec: maf must be in (0, 0.5]".into());
    }
    if !(cfg.annot_ratio > 0.0 && cfg.annot_ratio <= 1.0) {
        return Err("synth spec: annot must be in (0, 1]".into());
    }
    Ok(cfg)
}

/// Default admission cap on `hap * mark` for request-line panel specs (and
/// on `count * mark` for minted targets), so one serve request cannot make
/// the registry allocate an absurd amount of memory.  Trusted callers lift
/// it with [`PanelRegistry::unbounded`] / [`PanelRegistry::with_caps`].
const MAX_PANEL_STATES: usize = 1 << 24;

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = "synth:hap=8,mark=21,annot=0.2,seed=7";

    #[test]
    fn synth_specs_resolve_and_cache() {
        let reg = PanelRegistry::new();
        let a = reg.resolve(SPEC).unwrap();
        let b = reg.resolve(SPEC).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second resolve must hit the cache");
        assert_eq!(a.panel().n_hap(), 8);
        assert_eq!(a.panel().n_mark(), 21);
        assert_eq!(a.recipe().unwrap().seed, 7);
        assert!(a.sites().is_none());
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.names(), vec![SPEC.to_string()]);
    }

    #[test]
    fn unknown_and_malformed_names_are_errors() {
        let reg = PanelRegistry::new();
        assert!(reg.resolve("ukb-chr20").unwrap_err().contains("unknown panel"));
        assert!(reg.resolve("synth:hap=8").unwrap_err().contains("mark"));
        assert!(reg.resolve("synth:hap=8,mark=nope").is_err());
        assert!(reg.resolve("synth:hap=8,mark=21,zap=1").is_err());
        assert!(reg.resolve("synth:hap=1,mark=21").is_err());
        assert!(reg.is_empty(), "failed resolves must not cache");
    }

    #[test]
    fn out_of_range_specs_error_instead_of_panicking() {
        // These values trip asserts deeper in panelgen; the registry must
        // reject them as recoverable errors (requests are untrusted input).
        let reg = PanelRegistry::new();
        for bad in [
            "synth:hap=8,mark=21,maf=0.9",
            "synth:hap=8,mark=21,maf=0",
            "synth:hap=8,mark=21,annot=0",
            "synth:hap=8,mark=21,annot=2",
            "synth:hap=99999,mark=99999",
        ] {
            let err = reg.resolve(bad).unwrap_err();
            assert!(err.contains("synth spec"), "{bad}: {err}");
        }
        assert!(reg.is_empty());
    }

    #[test]
    fn registered_panels_resolve_by_name() {
        let reg = PanelRegistry::new();
        let cfg = PanelConfig {
            n_hap: 6,
            n_mark: 11,
            annot_ratio: 0.3,
            seed: 3,
            ..PanelConfig::default()
        };
        reg.register_synthetic("chip-a", &cfg);
        let p = reg.resolve("chip-a").unwrap();
        assert_eq!(p.panel().n_hap(), 6);
        let targets = p.synthetic_targets(2, 99).unwrap();
        assert_eq!(targets.len(), 2);
        assert_eq!(targets[0].n_mark(), 11);
        // Distinct seeds give distinct target sets.
        let other = p.synthetic_targets(2, 100).unwrap();
        assert_ne!(targets[0].obs, other[0].obs);
        // Same seed is reproducible.
        let again = p.synthetic_targets(2, 99).unwrap();
        assert_eq!(targets[0].obs, again[0].obs);
        // Absurd counts are admission errors, not multi-GB allocations.
        let err = p.synthetic_targets(usize::MAX / 2, 0).unwrap_err();
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn explicit_panels_have_no_recipe() {
        let reg = PanelRegistry::new();
        let cfg = PanelConfig {
            n_hap: 4,
            n_mark: 9,
            seed: 1,
            ..PanelConfig::default()
        };
        let p = reg.register("cohort", generate_panel(&cfg));
        assert!(p.recipe().is_none());
        assert!(p.synthetic_targets(1, 0).unwrap_err().contains("recipe"));
        let wl = p.workload(Vec::new()).unwrap();
        assert_eq!(wl.n_targets(), 0);
    }

    #[test]
    fn mosaic_targets_work_for_any_panel_and_are_deterministic() {
        let reg = PanelRegistry::new();
        let cfg = PanelConfig {
            n_hap: 6,
            n_mark: 20,
            seed: 5,
            ..PanelConfig::default()
        };
        let p = reg.register("cohort", generate_panel(&cfg));
        let cases = p.mosaic_targets(3, 0.25, 42).unwrap();
        assert_eq!(cases.len(), 3);
        for c in &cases {
            assert_eq!(c.truth.len(), 20);
            assert_eq!(c.masked.n_mark(), 20);
            // Masked to the 1-in-4 grid: some markers observed, most not.
            assert!(c.masked.n_annotated() >= 2);
            assert!(c.masked.n_annotated() < 20);
        }
        let again = p.mosaic_targets(3, 0.25, 42).unwrap();
        assert_eq!(cases[0].masked.obs, again[0].masked.obs);
        assert_eq!(cases[0].truth, again[0].truth);
        // Guard rails.
        assert!(p.mosaic_targets(1, 0.0, 0).is_err());
        assert!(p.mosaic_targets(usize::MAX / 2, 0.5, 0).unwrap_err().contains("cap"));
        // minted_targets falls back to the mosaic path without a recipe.
        let minted = p.minted_targets(2, 9).unwrap();
        assert_eq!(minted.len(), 2);
        assert_eq!(minted[0].n_mark(), 20);
    }

    #[test]
    fn spec_cache_evicts_least_recently_resolved() {
        let reg = PanelRegistry::with_capacity(2);
        let spec = |seed: u64| format!("synth:hap=4,mark=9,seed={seed}");
        reg.resolve(&spec(1)).unwrap();
        reg.resolve(&spec(2)).unwrap();
        // Touch 1 so 2 becomes the LRU victim.
        reg.resolve(&spec(1)).unwrap();
        reg.resolve(&spec(3)).unwrap();
        assert_eq!(reg.len(), 2);
        let names = reg.names();
        assert!(names.contains(&spec(1)), "{names:?}");
        assert!(names.contains(&spec(3)), "{names:?}");
        assert!(!names.contains(&spec(2)), "LRU entry must be evicted: {names:?}");
        // An evicted spec transparently reloads.
        assert_eq!(reg.resolve(&spec(2)).unwrap().panel().n_mark(), 9);
    }

    #[test]
    fn pinned_panels_survive_eviction_pressure() {
        let reg = PanelRegistry::with_capacity(1);
        let cfg = PanelConfig {
            n_hap: 4,
            n_mark: 9,
            seed: 8,
            ..PanelConfig::default()
        };
        let pinned = reg.register_synthetic("cohort", &cfg);
        for seed in 0..5 {
            reg.resolve(&format!("synth:hap=4,mark=9,seed={seed}")).unwrap();
        }
        // One unpinned survivor + the pinned panel.
        assert_eq!(reg.len(), 2);
        let resolved = reg.resolve("cohort").unwrap();
        assert!(Arc::ptr_eq(&pinned, &resolved), "pinned panel must never reload");
    }

    #[test]
    fn state_cap_is_registry_policy_not_a_global() {
        // A tiny cap rejects specs the default registry accepts...
        let strict = PanelRegistry::with_caps(4, 100);
        let err = strict.resolve("synth:hap=20,mark=20").unwrap_err();
        assert!(err.contains("cap of 100"), "{err}");
        // ...while small panels still load, and minted targets answer to
        // the same per-registry cap.
        let p = strict.resolve("synth:hap=4,mark=11").unwrap();
        let err = p.synthetic_targets(10, 0).unwrap_err(); // 110 obs > 100
        assert!(err.contains("cap"), "{err}");
        let err = p.mosaic_targets(10, 0.5, 0).unwrap_err();
        assert!(err.contains("cap"), "{err}");
        // The unbounded registry (the CLI's) accepts what serve rejects.
        let open = PanelRegistry::unbounded();
        assert!(open.resolve("synth:hap=20,mark=20").is_ok());
        let p = open.resolve("synth:hap=4,mark=11").unwrap();
        assert!(p.synthetic_targets(10, 0).is_ok());
    }

    #[test]
    fn file_backed_specs_resolve_and_fail_cleanly() {
        let reg = PanelRegistry::new();
        // Missing files and corrupt payloads are recoverable errors.
        assert!(
            reg.resolve("vcf:/nonexistent/panel.vcf").unwrap_err().contains("cannot read")
        );
        assert!(
            reg.resolve("packed:/nonexistent/panel.ppnl")
                .unwrap_err()
                .contains("cannot read")
        );
        let dir = std::env::temp_dir();
        let corrupt = dir.join(format!("poets-reg-corrupt-{}.ppnl", std::process::id()));
        std::fs::write(&corrupt, b"POETSPNL but not really").unwrap();
        let err = reg.resolve(&format!("packed:{}", corrupt.display())).unwrap_err();
        assert!(err.contains("truncated") || err.contains("checksum"), "{err}");
        let _ = std::fs::remove_file(&corrupt);
        assert!(reg.is_empty(), "failed loads must not cache");

        // A genuine .ppnl resolves, caches, and carries no recipe.
        let cfg = PanelConfig {
            n_hap: 4,
            n_mark: 11,
            seed: 2,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let path = dir.join(format!("poets-reg-good-{}.ppnl", std::process::id()));
        PackedPanel::from_panel(&panel).write(path.to_str().unwrap()).unwrap();
        let spec = format!("packed:{}", path.display());
        let p = reg.resolve(&spec).unwrap();
        let again = reg.resolve(&spec).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(Arc::ptr_eq(&p, &again), "second resolve must hit the cache");
        assert_eq!(p.panel().n_hap(), 4);
        assert_eq!(p.panel().n_mark(), 11);
        assert!(p.recipe().is_none());
        for m in 0..11 {
            assert_eq!(p.panel().column(m), panel.column(m));
        }
    }
}
