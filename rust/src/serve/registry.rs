//! The panel registry: named reference panels loaded once, cached behind
//! `Arc`, and handed out as shared [`Workload`]s.
//!
//! Panels are the heavy shared state of a multi-tenant imputation service —
//! a genuine panel is hundreds of MiB, so every concurrent request against
//! the same panel must share one in-memory copy.  The registry owns that
//! copy: [`PanelRegistry::resolve`] returns an `Arc`-shared
//! [`RegisteredPanel`], and [`RegisteredPanel::workload`] assembles a request
//! workload around the shared handle without copying panel data
//! ([`Workload::from_shared`]).
//!
//! Two ways for a panel to enter the registry:
//!
//! * **Explicit registration** ([`PanelRegistry::register`]) — the embedding
//!   application loads a cohort panel and names it.
//! * **Synthetic specs** — a panel name of the form
//!   `synth:hap=H,mark=M[,maf=F][,annot=R][,seed=S]` is generated on first
//!   use with the paper's §6.2 recipe and cached under that exact string.
//!   This keeps the `serve`/`bench-serve` CLI self-contained (no panel files
//!   in the offline environment) and makes request lines reproducible.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::model::panel::{ReferencePanel, TargetHaplotype};
use crate::session::Workload;
use crate::util::rng::Rng;
use crate::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

/// A panel held by the registry: the shared data plus (when synthetic) the
/// generation recipe, which lets the serve CLI mint matching targets and the
/// per-request report record provenance.
#[derive(Debug)]
pub struct RegisteredPanel {
    name: String,
    panel: Arc<ReferencePanel>,
    recipe: Option<PanelConfig>,
}

impl RegisteredPanel {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn panel(&self) -> &ReferencePanel {
        &self.panel
    }

    /// Shared handle to the panel data (cheap clone).
    pub fn panel_arc(&self) -> Arc<ReferencePanel> {
        Arc::clone(&self.panel)
    }

    /// Generation recipe, when the panel is synthetic.
    pub fn recipe(&self) -> Option<&PanelConfig> {
        self.recipe.as_ref()
    }

    /// Assemble a request workload around the shared panel (no panel copy).
    pub fn workload(&self, targets: Vec<TargetHaplotype>) -> Result<Workload, String> {
        Workload::from_shared(self.panel_arc(), targets)
    }

    /// Mint `count` masked targets from the panel's own recipe (synthetic
    /// panels only) — how serve clients without real cohort data, the CI
    /// smoke test and the load generator obtain valid request payloads.
    /// Distinct `seed`s give disjoint target sets.  Like the spec parser,
    /// this caps the total allocation (`count * n_mark`) because the count
    /// arrives from untrusted request lines.
    pub fn synthetic_targets(
        &self,
        count: usize,
        seed: u64,
    ) -> Result<Vec<TargetHaplotype>, String> {
        let recipe = self
            .recipe
            .ok_or_else(|| format!("panel {:?} has no synthetic recipe", self.name))?;
        if count.saturating_mul(self.panel.n_mark()) > MAX_SYNTH_STATES {
            return Err(format!(
                "{count} synthetic targets x {} markers exceeds the service cap \
                 of {MAX_SYNTH_STATES} observations",
                self.panel.n_mark()
            ));
        }
        let mut rng = Rng::new(seed ^ recipe.seed.rotate_left(17) ^ 0x5EED_7A26);
        Ok(generate_targets(&self.panel, &recipe, count, &mut rng)
            .into_iter()
            .map(|case| case.masked)
            .collect())
    }
}

/// Thread-safe name → panel cache.  `resolve` is what the serve workers call
/// on every coalesced batch; hits are one mutex lock + one `Arc` clone.
#[derive(Default)]
pub struct PanelRegistry {
    panels: Mutex<HashMap<String, Arc<RegisteredPanel>>>,
}

impl PanelRegistry {
    pub fn new() -> PanelRegistry {
        PanelRegistry::default()
    }

    /// Register a pre-loaded panel under `name` (replacing any previous
    /// holder of the name).  Returns the shared handle.
    pub fn register(&self, name: &str, panel: ReferencePanel) -> Arc<RegisteredPanel> {
        self.insert(RegisteredPanel {
            name: name.to_string(),
            panel: Arc::new(panel),
            recipe: None,
        })
    }

    /// Register a synthetic panel under `name`, generated from `cfg` now.
    /// The recipe is retained so `synthetic_targets` works.
    pub fn register_synthetic(&self, name: &str, cfg: &PanelConfig) -> Arc<RegisteredPanel> {
        self.insert(RegisteredPanel {
            name: name.to_string(),
            panel: Arc::new(generate_panel(cfg)),
            recipe: Some(*cfg),
        })
    }

    fn insert(&self, panel: RegisteredPanel) -> Arc<RegisteredPanel> {
        let shared = Arc::new(panel);
        self.panels
            .lock()
            .expect("panel registry poisoned")
            .insert(shared.name.clone(), Arc::clone(&shared));
        shared
    }

    /// Look up `name`, generating and caching `synth:` specs on first use.
    ///
    /// The cache key is the exact spec string, so two spellings of the same
    /// recipe (`synth:hap=8,mark=21` vs `synth:mark=21,hap=8`) cache
    /// separately — canonicalise spellings client-side if that matters.
    pub fn resolve(&self, name: &str) -> Result<Arc<RegisteredPanel>, String> {
        let mut panels = self.panels.lock().expect("panel registry poisoned");
        if let Some(p) = panels.get(name) {
            return Ok(Arc::clone(p));
        }
        let Some(spec) = name.strip_prefix("synth:") else {
            return Err(format!(
                "unknown panel {name:?} (register it, or use a synth:hap=..,mark=.. spec)"
            ));
        };
        // Generate while holding the lock: concurrent first requests for the
        // same spec then build it exactly once (generation is fast relative
        // to imputation; a successor can move to per-entry once-cells if a
        // huge synthetic panel ever stalls the registry).
        let cfg = parse_synth_spec(spec)?;
        let shared = Arc::new(RegisteredPanel {
            name: name.to_string(),
            panel: Arc::new(generate_panel(&cfg)),
            recipe: Some(cfg),
        });
        panels.insert(name.to_string(), Arc::clone(&shared));
        Ok(shared)
    }

    /// Names currently cached (sorted, for `info`-style listings).
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .panels
            .lock()
            .expect("panel registry poisoned")
            .keys()
            .cloned()
            .collect();
        names.sort();
        names
    }

    pub fn len(&self) -> usize {
        self.panels.lock().expect("panel registry poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Parse the body of a `synth:` panel name: comma-separated `key=value`
/// pairs.  `hap` and `mark` are required; `maf`, `annot`, `seed` default to
/// the paper's recipe (0.05, 0.1, 0).
fn parse_synth_spec(spec: &str) -> Result<PanelConfig, String> {
    let mut cfg = PanelConfig {
        annot_ratio: 0.1,
        ..PanelConfig::default()
    };
    let (mut saw_hap, mut saw_mark) = (false, false);
    for field in spec.split(',') {
        let field = field.trim();
        if field.is_empty() {
            continue;
        }
        let Some((key, value)) = field.split_once('=') else {
            return Err(format!("synth spec field {field:?} is not key=value"));
        };
        fn parse_field<T: std::str::FromStr>(key: &str, value: &str) -> Result<T, String> {
            value
                .trim()
                .parse()
                .map_err(|_| format!("synth spec: cannot parse {key}={value:?}"))
        }
        match key.trim() {
            "hap" => {
                cfg.n_hap = parse_field(key, value)?;
                saw_hap = true;
            }
            "mark" => {
                cfg.n_mark = parse_field(key, value)?;
                saw_mark = true;
            }
            "maf" => cfg.maf = parse_field(key, value)?,
            "annot" => cfg.annot_ratio = parse_field(key, value)?,
            "seed" => cfg.seed = parse_field(key, value)?,
            other => {
                return Err(format!(
                    "synth spec: unknown key {other:?} (expected hap|mark|maf|annot|seed)"
                ));
            }
        }
    }
    if !saw_hap || !saw_mark {
        return Err("synth spec needs at least hap=.. and mark=..".into());
    }
    // Specs arrive from untrusted request lines: every range that would
    // trip an assert (and panic the service) deeper in panelgen must be
    // rejected here with a recoverable error instead.
    if cfg.n_hap < 2 || cfg.n_mark < 2 {
        return Err("synth spec: hap and mark must be >= 2".into());
    }
    if cfg.n_hap.saturating_mul(cfg.n_mark) > MAX_SYNTH_STATES {
        return Err(format!(
            "synth spec: hap*mark = {} exceeds the service cap of {MAX_SYNTH_STATES} states",
            cfg.n_hap.saturating_mul(cfg.n_mark)
        ));
    }
    if !(cfg.maf > 0.0 && cfg.maf <= 0.5) {
        return Err("synth spec: maf must be in (0, 0.5]".into());
    }
    if !(cfg.annot_ratio > 0.0 && cfg.annot_ratio <= 1.0) {
        return Err("synth spec: annot must be in (0, 1]".into());
    }
    Ok(cfg)
}

/// Admission cap on `hap * mark` for request-line synth specs (and on
/// `count * mark` for minted targets), so one request cannot make the
/// registry allocate an absurd amount of memory.
const MAX_SYNTH_STATES: usize = 1 << 24;

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = "synth:hap=8,mark=21,annot=0.2,seed=7";

    #[test]
    fn synth_specs_resolve_and_cache() {
        let reg = PanelRegistry::new();
        let a = reg.resolve(SPEC).unwrap();
        let b = reg.resolve(SPEC).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second resolve must hit the cache");
        assert_eq!(a.panel().n_hap(), 8);
        assert_eq!(a.panel().n_mark(), 21);
        assert_eq!(a.recipe().unwrap().seed, 7);
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.names(), vec![SPEC.to_string()]);
    }

    #[test]
    fn unknown_and_malformed_names_are_errors() {
        let reg = PanelRegistry::new();
        assert!(reg.resolve("ukb-chr20").unwrap_err().contains("unknown panel"));
        assert!(reg.resolve("synth:hap=8").unwrap_err().contains("mark"));
        assert!(reg.resolve("synth:hap=8,mark=nope").is_err());
        assert!(reg.resolve("synth:hap=8,mark=21,zap=1").is_err());
        assert!(reg.resolve("synth:hap=1,mark=21").is_err());
        assert!(reg.is_empty(), "failed resolves must not cache");
    }

    #[test]
    fn out_of_range_specs_error_instead_of_panicking() {
        // These values trip asserts deeper in panelgen; the registry must
        // reject them as recoverable errors (requests are untrusted input).
        let reg = PanelRegistry::new();
        for bad in [
            "synth:hap=8,mark=21,maf=0.9",
            "synth:hap=8,mark=21,maf=0",
            "synth:hap=8,mark=21,annot=0",
            "synth:hap=8,mark=21,annot=2",
            "synth:hap=99999,mark=99999",
        ] {
            let err = reg.resolve(bad).unwrap_err();
            assert!(err.contains("synth spec"), "{bad}: {err}");
        }
        assert!(reg.is_empty());
    }

    #[test]
    fn registered_panels_resolve_by_name() {
        let reg = PanelRegistry::new();
        let cfg = PanelConfig {
            n_hap: 6,
            n_mark: 11,
            annot_ratio: 0.3,
            seed: 3,
            ..PanelConfig::default()
        };
        reg.register_synthetic("chip-a", &cfg);
        let p = reg.resolve("chip-a").unwrap();
        assert_eq!(p.panel().n_hap(), 6);
        let targets = p.synthetic_targets(2, 99).unwrap();
        assert_eq!(targets.len(), 2);
        assert_eq!(targets[0].n_mark(), 11);
        // Distinct seeds give distinct target sets.
        let other = p.synthetic_targets(2, 100).unwrap();
        assert_ne!(targets[0].obs, other[0].obs);
        // Same seed is reproducible.
        let again = p.synthetic_targets(2, 99).unwrap();
        assert_eq!(targets[0].obs, again[0].obs);
        // Absurd counts are admission errors, not multi-GB allocations.
        let err = p.synthetic_targets(usize::MAX / 2, 0).unwrap_err();
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn explicit_panels_have_no_recipe() {
        let reg = PanelRegistry::new();
        let cfg = PanelConfig {
            n_hap: 4,
            n_mark: 9,
            seed: 1,
            ..PanelConfig::default()
        };
        let p = reg.register("cohort", generate_panel(&cfg));
        assert!(p.recipe().is_none());
        assert!(p.synthetic_targets(1, 0).unwrap_err().contains("recipe"));
        let wl = p.workload(Vec::new()).unwrap();
        assert_eq!(wl.n_targets(), 0);
    }
}
