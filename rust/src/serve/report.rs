//! The per-request result of a served imputation: the session's
//! [`ImputeReport`] manifest plus the service-side observability fields,
//! serialised as schema **`poets-impute/serve-report/v1`**.
//!
//! ## Schema (`poets-impute/serve-report/v1`)
//!
//! The JSON document is the `poets-impute/impute-report/v1` manifest (see
//! [`crate::session::report`]) with three changes:
//!
//! * `"schema"` is `"poets-impute/serve-report/v1"`;
//! * a `"serve"` section carries the service-side fields:
//!   - `request_id` — the service-assigned admission id,
//!   - `panel` — the registry name the request resolved against,
//!   - `batch_id` — which coalesced engine batch served this request,
//!   - `coalesce_width` — how many requests shared that batch (1 = no
//!     coalescing happened, whether disabled or just no concurrent traffic),
//!   - `queue_wait_seconds` — admission → batch-start wait,
//!   - `worker` — which pool worker ran the batch,
//!   - `spans` — present only when the request set `"spans": true`: the
//!     [`RequestSpan`] phase timeline as microsecond offsets from the
//!     submit instant (`admitted_us <= dequeued_us <= minted_us <=
//!     prepared_us <= run_us <= responded_us`, guaranteed monotone), plus
//!     `coalesced_with` (batch width) and `merged_wave` (whether an
//!     event-plane group ran this request inside one shared wave sweep);
//! * a `"dosages"` array (`dosages[target][marker]`) — unlike the archived
//!   bench manifest, a service response must carry the actual answer.
//!
//! Everything else (`workload`, `run`, `timing`, optional `accuracy` /
//! `sim_metrics` sections) is exactly the impute-report layout, so tooling
//! that reads one schema reads both.
//!
//! Related formats: the `workload.panel` key (when present) names the
//! registry spec the request resolved — for file-backed panels that is a
//! `packed:<path>` spec whose on-disk `.ppnl` layout is documented in
//! [`crate::genomics::packed`], or a `vcf:<path>` spec parsed by
//! [`crate::genomics::vcf`].  The DES-side observability sibling — schema
//! `poets-impute/trace/v1`, the per-superstep JSONL trace written by
//! `impute --trace` and consumed by the `trace` CLI verb — is documented
//! in [`crate::obs::trace`].
//!
//! ## The wire family
//!
//! The serve plane speaks the same JSON documents over two transports
//! (identical bytes on both, asserted in `tests/serve_roundtrip.rs`):
//!
//! * **stdin JSONL** — one request per line in, one response document per
//!   line out (`poets-impute serve`);
//! * **framed TCP** — each document prefixed by a big-endian `u32` payload
//!   length (`poets-impute serve --tcp ADDR`, cap 64 MiB per frame; see
//!   [`crate::serve::net::frame`]).  `serve --connect ADDR` bridges a JSONL
//!   pipe onto this transport.
//!
//! Besides `serve-report/v1`, three sibling schemas travel the same wire:
//!
//! * **`serve-error/v1`** — `{"id", "ok": false, "error"}`.  The `error`
//!   string is prefixed by its shed class: `admission:` (queue full,
//!   malformed request, unknown panel), `quota:` (per-tenant token bucket
//!   empty — see `tenant` below), `deadline:` (predicted queue wait already
//!   exceeds the request's `deadline_ms` budget).  `frame:` errors report a
//!   malformed TCP frame before a request id exists.
//! * **`serve-report-part/v1`** — one streamed window of a
//!   `"window"`/`"stream"` request ([`crate::serve::ServePart`]):
//!   `{"id", "schema", "part", "request_id", "window", "n_windows",
//!   "core_start", "core_end", "dosages"}` where `dosages[target][marker]`
//!   covers `core_start..core_end`.  Parts arrive in window order and are
//!   followed by a terminal manifest — this document with `"streamed": true`,
//!   `"parts"` (the part count) and **no** top-level `dosages` array.
//! * **`serve-stats/v1`** — reply to the `{"stats": true}` admin verb:
//!   `{"id", "ok": true, "schema", "shards", "panels_cached", "totals",
//!   "per_shard"}`.  `totals` merges every shard's counters (`accepted`,
//!   `rejected`, `completed`, `failed`, `batches`, `coalesced_requests`,
//!   `merged_waves`, `shed_quota`, `shed_deadline`, `mean_batch_width`,
//!   the worker engine-cache counters `cache_hits` / `cache_misses` /
//!   `cache_evictions`, and two 16-element histograms `queue_wait_hist` /
//!   `service_hist` — log2-µs buckets where index `i` counts values in
//!   `[2^i, 2^(i+1))` µs, saturating at the last bucket; see
//!   [`crate::obs::bucket_bounds`]); `per_shard` repeats them per shard
//!   plus `shard` and live `queue_depth`.  While a shutdown is draining
//!   the reply carries `"draining": true`.
//!
//! Request-side knobs that shape these responses: `tenant` (string) selects
//! the token bucket that `quota:` sheds debit; `deadline_ms` (non-negative
//! integer) arms the `deadline:` admission check; `window`/`overlap` +
//! `"stream": true` switch the response from one document to the
//! parts-then-manifest sequence above.  Full request grammar:
//! [`crate::serve::jsonl`].

use crate::session::ImputeReport;
use crate::util::json::Json;

use super::queue::RequestSpan;

/// Everything the service produced for one request.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Service-assigned admission id (monotonic per service).
    pub request_id: u64,
    /// Registry name of the panel the request ran against.
    pub panel: String,
    /// Coalesced engine batch that served this request.
    pub batch_id: u64,
    /// Requests sharing that batch (1 = ran alone).
    pub coalesce_width: usize,
    /// Seconds between admission and the batch starting to execute.
    pub queue_wait_seconds: f64,
    /// Pool worker index that ran the batch.
    pub worker: usize,
    /// The underlying per-request run manifest + dosages.
    pub report: ImputeReport,
    /// Phase timeline, present only when the request opted in with
    /// `"spans": true` — serialised as the `serve.spans` object.
    pub span: Option<RequestSpan>,
}

impl ServeReport {
    /// The response document (schema `poets-impute/serve-report/v1`).
    pub fn to_json(&self) -> Json {
        let mut j = self.report.to_json();
        j.set("schema", "poets-impute/serve-report/v1");

        let mut serve = Json::obj();
        serve
            .set("request_id", self.request_id)
            .set("panel", self.panel.as_str())
            .set("batch_id", self.batch_id)
            .set("coalesce_width", self.coalesce_width)
            .set("queue_wait_seconds", self.queue_wait_seconds)
            .set("worker", self.worker);
        if let Some(sp) = &self.span {
            let mut spans = Json::obj();
            spans
                .set("admitted_us", sp.admitted_us)
                .set("dequeued_us", sp.dequeued_us)
                .set("minted_us", sp.minted_us)
                .set("prepared_us", sp.prepared_us)
                .set("run_us", sp.run_us)
                .set("responded_us", sp.responded_us)
                .set("coalesced_with", sp.coalesced_with as u64)
                .set("merged_wave", sp.merged_wave);
            serve.set("spans", spans);
        }
        j.set("serve", serve);

        let dosages: Vec<Json> = self
            .report
            .dosages
            .iter()
            .map(|row| Json::Arr(row.iter().map(|&d| Json::Num(d as f64)).collect()))
            .collect();
        j.set("dosages", Json::Arr(dosages));
        j
    }

    /// `dosages[target][marker]` for this request, in submission order.
    pub fn dosages(&self) -> &[Vec<f32>] {
        &self.report.dosages
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::mapping::MappingStrategy;
    use crate::session::EngineSpec;

    fn report() -> ServeReport {
        ServeReport {
            request_id: 7,
            panel: "synth:hap=8,mark=3".into(),
            batch_id: 2,
            coalesce_width: 3,
            queue_wait_seconds: 0.004,
            worker: 1,
            report: ImputeReport {
                engine: EngineSpec::Rank1,
                n_hap: 8,
                n_mark: 3,
                n_targets: 2,
                panel: Some("synth:hap=8,mark=3".into()),
                provenance: None,
                batch_size: 2,
                n_batches: 1,
                windows: None,
                boards: 2,
                states_per_thread: 8,
                threads: 1,
                mapping: MappingStrategy::Manual2d,
                dosages: vec![vec![0.5, 0.25, 1.0], vec![0.0, 0.75, 0.5]],
                accuracy: None,
                host_seconds: 0.01,
                sim_seconds: None,
                metrics: None,
                stream: None,
                trace: None,
            },
            span: None,
        }
    }

    #[test]
    fn schema_overrides_impute_report() {
        let j = report().to_json();
        assert_eq!(
            j.get("schema"),
            Some(&Json::Str("poets-impute/serve-report/v1".into()))
        );
        // The impute-report sections survive untouched.
        for key in ["engine", "workload", "run", "timing"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn spans_serialise_only_when_present() {
        let j = report().to_json();
        assert!(j.get("serve").unwrap().get("spans").is_none(), "opt-in");

        let mut r = report();
        r.span = Some(RequestSpan {
            admitted_us: 1,
            dequeued_us: 2,
            minted_us: 3,
            prepared_us: 4,
            run_us: 5,
            responded_us: 6,
            coalesced_with: 3,
            merged_wave: true,
        });
        let j = r.to_json();
        let sp = j.get("serve").unwrap().get("spans").expect("spans block");
        assert_eq!(sp.get("admitted_us"), Some(&Json::Int(1)));
        assert_eq!(sp.get("responded_us"), Some(&Json::Int(6)));
        assert_eq!(sp.get("coalesced_with"), Some(&Json::Int(3)));
        assert_eq!(sp.get("merged_wave"), Some(&Json::Bool(true)));
    }

    #[test]
    fn serve_section_and_dosages_present() {
        let j = report().to_json();
        let s = j.get("serve").unwrap();
        assert_eq!(s.get("request_id").unwrap().as_i64(), Some(7));
        assert_eq!(s.get("batch_id").unwrap().as_i64(), Some(2));
        assert_eq!(s.get("coalesce_width").unwrap().as_i64(), Some(3));
        assert_eq!(s.get("worker").unwrap().as_i64(), Some(1));
        assert!(s.get("queue_wait_seconds").unwrap().as_f64().unwrap() > 0.0);
        let d = j.get("dosages").unwrap().as_arr().unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].as_arr().unwrap().len(), 3);
        // Round-trips through the parser (what the CLI client sees).
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back.get("serve").unwrap().get("panel").unwrap().as_str(),
                   Some("synth:hap=8,mark=3"));
    }
}
