//! `serve::shard` — panel-sharded worker pools.
//!
//! A [`ShardedService`] is N independent [`Service`]s sharing one
//! [`PanelRegistry`]: each request hashes its panel name (FNV-1a, stable
//! across runs and platforms) to pick a shard, so every panel's traffic
//! lands on one shard's admission queue, worker pool and engine caches.
//! Hot panels scale by adding shards without cold panels evicting their
//! engines, and one panel's backlog (or quota/deadline shedding) never
//! queues behind another shard's work.  `shards = 1` is exactly the
//! single-`Service` behaviour, which is how the stdin frontend runs by
//! default.
//!
//! Coalescing is unaffected: same-panel requests land on the same shard by
//! construction, so the per-shard coalescer sees the same merge
//! opportunities a single queue would.

use std::sync::Arc;

use super::queue::{ImputeRequest, ServiceStats, Ticket};
use super::report::ServeReport;
use super::{PanelRegistry, ServeConfig, Service};

/// Stable FNV-1a (64-bit) over the panel name — the shard routing hash.
/// `std::collections::hash_map::DefaultHasher` is documented as unstable
/// across releases; routing must not silently change between builds.
pub fn shard_of(panel: &str, shards: usize) -> usize {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    for b in panel.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    (h % shards.max(1) as u64) as usize
}

/// One shard's observable state (for the `stats` verb and the load bench).
#[derive(Clone, Copy, Debug)]
pub struct ShardSnapshot {
    /// Shard index (also the routing hash bucket).
    pub shard: usize,
    /// Requests waiting in this shard's queue right now.
    pub queue_depth: usize,
    /// This shard's counters.
    pub stats: ServiceStats,
}

/// N panel-sharded [`Service`]s behind one submit surface.
pub struct ShardedService {
    shards: Vec<Service>,
    registry: Arc<PanelRegistry>,
}

impl ShardedService {
    /// Start `shards` services (each with `cfg`'s worker pool, queue and
    /// quota settings) over one shared registry.
    pub fn start(registry: Arc<PanelRegistry>, cfg: ServeConfig, shards: usize) -> ShardedService {
        let n = shards.max(1);
        let shards = (0..n)
            .map(|_| Service::start(Arc::clone(&registry), cfg.clone()))
            .collect();
        ShardedService { shards, registry }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard that will serve `panel`.
    pub fn shard_for(&self, panel: &str) -> &Service {
        &self.shards[shard_of(panel, self.shards.len())]
    }

    /// Route a request to its panel's shard (admission semantics are the
    /// shard's — see [`Service::submit`]).
    pub fn submit(&self, req: ImputeRequest) -> Result<Ticket, String> {
        self.shard_for(&req.panel).submit(req)
    }

    /// Submit and block for the result.
    pub fn submit_wait(&self, req: ImputeRequest) -> Result<ServeReport, String> {
        self.submit(req)?.wait()
    }

    /// The shared panel registry.
    pub fn registry(&self) -> &Arc<PanelRegistry> {
        &self.registry
    }

    /// The configuration shards were started with.
    pub fn config(&self) -> &ServeConfig {
        self.shards[0].config()
    }

    /// Aggregate counters over every shard.
    pub fn stats(&self) -> ServiceStats {
        self.shards
            .iter()
            .fold(ServiceStats::default(), |acc, s| acc.merge(&s.stats()))
    }

    /// Per-shard queue depth + counters, in shard order.
    pub fn shard_snapshots(&self) -> Vec<ShardSnapshot> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| ShardSnapshot {
                shard: i,
                queue_depth: s.queue_depth(),
                stats: s.stats(),
            })
            .collect()
    }

    /// Stop admitting, drain every shard's admitted requests, join all
    /// workers, and return the merged counters.
    pub fn shutdown(self) -> ServiceStats {
        self.shards
            .into_iter()
            .fold(ServiceStats::default(), |acc, s| acc.merge(&s.shutdown()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::EngineSpec;

    #[test]
    fn routing_is_stable_and_in_range() {
        // FNV-1a is a fixed function: these assignments must never change
        // across builds (routing is part of the service's observable
        // behaviour).
        assert_eq!(shard_of("synth:hap=8,mark=21,annot=0.2,seed=11", 1), 0);
        for shards in 1..=8 {
            for name in ["a", "b", "panel-x", "synth:hap=8,mark=41,seed=1"] {
                assert!(shard_of(name, shards) < shards);
            }
        }
        // Same name, same shard; sanity that different names CAN differ.
        assert_eq!(shard_of("abc", 4), shard_of("abc", 4));
        let spread: std::collections::BTreeSet<usize> = (0..32)
            .map(|i| shard_of(&format!("panel-{i}"), 4))
            .collect();
        assert!(spread.len() > 1, "32 names must not all hash to one of 4 shards");
    }

    #[test]
    fn sharded_submit_routes_serves_and_aggregates() {
        let registry = Arc::new(PanelRegistry::new());
        let svc = ShardedService::start(registry, ServeConfig::default().workers(1), 3);
        assert_eq!(svc.n_shards(), 3);

        // Two panels, very likely on different shards — but the contract
        // holds either way: every request completes and the aggregate
        // counters see all of them.
        let specs = [
            "synth:hap=8,mark=21,annot=0.2,seed=1",
            "synth:hap=8,mark=21,annot=0.2,seed=2",
        ];
        for spec in specs {
            let panel = svc.registry().resolve(spec).unwrap();
            let targets = panel.synthetic_targets(1, 7).unwrap();
            let report = svc
                .submit_wait(ImputeRequest::new(spec, EngineSpec::Rank1, targets))
                .unwrap();
            assert_eq!(report.panel, spec);
        }

        let snapshots = svc.shard_snapshots();
        assert_eq!(snapshots.len(), 3);
        // Routing determinism: each shard completed exactly the requests
        // whose panel hashes to it.
        let mut expected = [0u64; 3];
        for spec in specs {
            expected[shard_of(spec, 3)] += 1;
        }
        for (i, snap) in snapshots.iter().enumerate() {
            assert_eq!(snap.stats.completed, expected[i], "shard {i}");
        }
        let stats = svc.shutdown();
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.accepted, 2);
    }
}
