//! Leader entrypoint for the `poets-impute` CLI.
//!
//! See `poets-impute help` for the list of subcommands. The binary is fully
//! self-contained at run time: Python/JAX participate only in `make artifacts`.

fn main() {
    let code = poets_impute::cli::run(std::env::args().skip(1).collect());
    std::process::exit(code);
}
